//! Integration tests for the autoregressive serving path (DESIGN.md
//! §10): continuous-batching invariants (retire/join, disjoint cluster
//! ownership, ≥1 cluster per live request), serving metrics (TTFT,
//! tokens, per-token latency), decode-phase backend agreement, and the
//! engine queue semantics the batching loop builds on.

use vexp::coordinator::CLUSTERS;
use vexp::exec::{AnalyticBackend, Backend, CycleSimBackend, Engine, Request, ServeOptions, ServeReport};
use vexp::model::{Phase, TransformerConfig, GPT2_SMALL, VIT_BASE};

/// A small GPT-2 shape (short prompt) to keep simulated prefills cheap.
fn tiny_gpt2(prompt: u32) -> TransformerConfig {
    let mut cfg = GPT2_SMALL;
    cfg.seq = prompt;
    cfg
}

fn ratio(a: f64, b: f64) -> f64 {
    assert!(a > 0.0 && b > 0.0, "non-positive cycle counts: {a} vs {b}");
    a / b
}

/// Check the continuous-batching schedule invariants on a run's log:
/// cluster sets disjoint per iteration, every live request owns at
/// least one cluster, arrivals respected, retired requests never
/// rescheduled.
fn assert_schedule_invariants(report: &ServeReport, arrivals: &[(u64, u32)]) {
    let mut last_seen: std::collections::HashMap<u64, u32> = Default::default();
    for rec in &report.log {
        let mut owned = vec![false; CLUSTERS];
        assert!(!rec.entries.is_empty(), "iteration {} scheduled nobody", rec.iter);
        for e in &rec.entries {
            assert!(!e.clusters.is_empty(), "request {} got no cluster", e.id);
            for &c in &e.clusters {
                assert!(c < CLUSTERS, "cluster index {c} out of range");
                assert!(!owned[c], "cluster {c} owned twice in iteration {}", rec.iter);
                owned[c] = true;
            }
            if let Some(&(_, arrival)) = arrivals.iter().find(|&&(id, _)| id == e.id) {
                assert!(
                    rec.iter >= arrival,
                    "request {} scheduled at iteration {} before its arrival {}",
                    e.id,
                    rec.iter,
                    arrival
                );
            }
            last_seen.insert(e.id, rec.iter);
        }
    }
    // a retired request must not appear after its last iteration: the
    // log's last sighting of each id must be monotone in retirement
    // order is implied by construction; here we check every request
    // appears at least once
    for &(id, _) in arrivals {
        assert!(last_seen.contains_key(&id), "request {id} never scheduled");
    }
}

#[test]
fn continuous_batching_retires_joins_and_reports_metrics() {
    let mut engine = Engine::new();
    let a = engine.submit_request(Request::new(0, tiny_gpt2(64)).with_tokens(3));
    let b = engine.submit_request(Request::new(0, VIT_BASE)); // prefill-only
    let c = engine.submit_request(Request::new(0, tiny_gpt2(64)).with_tokens(2).arriving_at(2));
    assert_eq!((a, b, c), (0, 1, 2));

    let mut backend = AnalyticBackend::new();
    let report = engine.serve(&mut backend, None, &ServeOptions::default());
    report.assert_consistent();
    assert_eq!(report.per_request.len(), 3, "every request retires");
    assert_eq!(engine.pending(), 0);

    assert_schedule_invariants(&report, &[(a, 0), (b, 0), (c, 2)]);

    // the late request must be absent from iterations before its arrival
    for rec in report.log.iter().filter(|r| r.iter < 2) {
        assert!(
            rec.entries.iter().all(|e| e.id != c),
            "request {c} joined before its arrival iteration"
        );
    }
    // ... and present afterwards (it has 2+ iterations of work)
    assert!(
        report
            .log
            .iter()
            .any(|r| r.iter >= 2 && r.entries.iter().any(|e| e.id == c)),
        "late request never joined"
    );

    for r in &report.per_request {
        assert!(r.cycles > 0.0);
        assert!(r.energy_pj > 0.0);
        assert!(r.ttft_cycles > 0.0, "{}: TTFT missing", r.request_id);
        assert!(r.clusters_used >= 1);
    }
    let ra = report.per_request.iter().find(|r| r.request_id == a).unwrap();
    assert_eq!(ra.tokens, 3, "token target met");
    assert!(ra.decode_token_cycles > 0.0, "decode iterations ran");
    assert!(ra.tokens_per_s() > 0.0);
    let rb = report.per_request.iter().find(|r| r.request_id == b).unwrap();
    assert_eq!(rb.tokens, 0, "prefill-only request generates no tokens");
    assert_eq!(rb.decode_token_cycles, 0.0);

    // retirement frees clusters: after the ViT tenant (1 iteration)
    // retires, survivors repartition the grid
    let first = &report.log[0];
    let total_first: usize = first.entries.iter().map(|e| e.clusters.len()).sum();
    assert!(total_first <= CLUSTERS);
    assert_eq!(report.total_tokens(), 3 + 0 + 2);
    assert!(report.tokens_per_s() > 0.0);
}

#[test]
fn continuous_batching_on_the_cycle_sim_backend() {
    // small shapes: one prefill + two decode iterations, for real
    let mut engine = Engine::with_clusters(4);
    let id = engine.submit_request(Request::new(0, tiny_gpt2(64)).with_tokens(3));
    let mut backend = CycleSimBackend::new(4);
    let report = engine.serve(&mut backend, None, &ServeOptions::default());
    report.assert_consistent();
    assert_eq!(report.per_request.len(), 1);
    let r = &report.per_request[0];
    assert_eq!(r.request_id, id);
    assert_eq!(r.tokens, 3);
    assert!(r.ttft_cycles > 0.0);
    assert!(r.decode_token_cycles > 0.0);
    assert!(
        r.ttft_cycles > r.decode_token_cycles,
        "prefilling a 64-token prompt must cost more than one decode step: {} vs {}",
        r.ttft_cycles,
        r.decode_token_cycles
    );
    // 1 prefill + 2 decode iterations
    assert_eq!(report.iterations, 3);
    assert_eq!(report.backend, "cycle-sim");
}

#[test]
fn decode_program_is_cached_across_iterations() {
    let mut engine = Engine::with_clusters(4);
    engine.submit_request(Request::new(0, tiny_gpt2(64)).with_tokens(4));
    let mut backend = AnalyticBackend::new();
    let report = engine.serve(&mut backend, None, &ServeOptions::default());
    report.assert_consistent();
    assert_eq!(report.iterations, 4, "1 prefill + 3 decode iterations");
    // one prefill program + one decode program; every later iteration
    // hits the cache even though the KV length grows
    assert_eq!(engine.cache.misses, 2, "exactly two distinct programs compiled");
    assert!(engine.cache.hits >= 2, "decode iterations reuse the cached slice");
}

#[test]
fn decode_slice_backends_agree_within_prefill_tolerance() {
    let mut analytic = AnalyticBackend::new();
    let mut cyclesim = CycleSimBackend::new(CLUSTERS);
    for kv in [512u32, 2048] {
        let req = Request::new(0, GPT2_SMALL);
        let phase = Phase::Decode { kv_len: kv };
        let a = analytic.estimate_phase(&req, phase);
        let c = cyclesim.estimate_phase(&req, phase);
        assert_eq!(a.tokens, 1);
        assert_eq!(c.tokens, 1);
        let attn = ratio(a.attn_cycles, c.attn_cycles);
        assert!(
            (0.25..=4.0).contains(&attn),
            "kv={kv}: decode attention disagrees: analytic {:.3e} vs cycle-sim {:.3e} (ratio {attn:.2})",
            a.attn_cycles,
            c.attn_cycles
        );
        let total = ratio(a.cycles, c.cycles);
        assert!(
            (0.25..=4.0).contains(&total),
            "kv={kv}: decode total disagrees: ratio {total:.2}"
        );
    }
}

#[test]
fn decode_step_cost_grows_with_kv_on_both_backends() {
    let mut analytic = AnalyticBackend::new();
    let mut cyclesim = CycleSimBackend::new(CLUSTERS);
    for backend in [&mut analytic as &mut dyn Backend, &mut cyclesim] {
        let req = Request::new(0, GPT2_SMALL);
        let short = backend.estimate_phase(&req, Phase::Decode { kv_len: 256 });
        let long = backend.estimate_phase(&req, Phase::Decode { kv_len: 2048 });
        assert!(
            long.attn_cycles > 2.0 * short.attn_cycles,
            "{}: attention must scale with KV length ({} vs {})",
            backend.name(),
            long.attn_cycles,
            short.attn_cycles
        );
        // a decode step stays far below a full forward pass
        let full = backend.estimate(&req);
        assert!(long.cycles * 10.0 < full.cycles, "{}: decode step too expensive", backend.name());
    }
}

#[test]
fn phased_batch_executes_on_the_cycle_sim_backend() {
    // one prefill + one decode tenant sharing the grid, executed for real
    let sched = vexp::exec::BatchScheduler::new(CLUSTERS);
    let mut cache = vexp::exec::ProgramCache::new();
    let entries = [
        (Request::new(0, tiny_gpt2(64)), Phase::Prefill { prompt: 64 }),
        (Request::new(1, GPT2_SMALL), Phase::Decode { kv_len: 512 }),
    ];
    let batch = sched.compile_phased(&entries, &mut cache);
    assert_eq!(batch.requests.len(), 2);
    assert!(batch.requests[0].reps >= batch.requests[0].rounds);
    assert!(batch.requests[1].phase.is_decode());

    let mut sim = CycleSimBackend::new(CLUSTERS);
    let report = sim.execute(&batch);
    assert_eq!(report.per_request.len(), 2);
    for (cr, r) in batch.requests.iter().zip(&report.per_request) {
        assert!(r.cycles > 0.0, "{}: no measured cycles", r.model);
        assert!(r.energy_pj > 0.0);
        assert_eq!(r.clusters_used, cr.clusters.len());
        for cs in &r.per_cluster {
            assert!(cs.combined().retired_total() > 0, "real simulation evidence");
        }
    }
    // the analytic backend rates the same phased batch within a loose band
    let mut analytic = AnalyticBackend::new();
    let rated = analytic.execute(&batch);
    for (m, a) in report.per_request.iter().zip(&rated.per_request) {
        let r = m.cycles / a.cycles;
        assert!(
            (0.2..=5.0).contains(&r),
            "{}: cycle-sim {:.0} vs analytic {:.0} (ratio {r:.2})",
            m.model,
            m.cycles,
            a.cycles
        );
    }
}

#[test]
fn serve_with_empty_queue_is_empty() {
    let mut engine = Engine::new();
    let mut backend = AnalyticBackend::new();
    let report = engine.serve(&mut backend, None, &ServeOptions::default());
    report.assert_consistent();
    assert_eq!(report.iterations, 0);
    assert_eq!(report.total_cycles, 0);
    assert!(report.per_request.is_empty());
    assert!(report.log.is_empty());
    assert_eq!(report.tokens_per_s(), 0.0);
}

#[test]
fn safety_bound_reports_unfinished_requests() {
    let mut engine = Engine::with_clusters(4);
    engine.submit_request(Request::new(0, tiny_gpt2(64)).with_tokens(1000));
    let mut backend = AnalyticBackend::new();
    let report = engine.serve(&mut backend, None, &ServeOptions::legacy(3));
    report.assert_consistent();
    assert_eq!(report.iterations, 3);
    assert_eq!(report.per_request.len(), 1, "unfinished request still reported");
    let r = &report.per_request[0];
    assert!(r.tokens < 1000, "bounded run cannot meet the target");
    assert!(r.tokens >= 1, "prefill produced the first token");
}

#[test]
fn safety_bound_reports_never_admitted_requests_with_zero_progress() {
    // a 1-cluster engine can hold one live request; the bound of 1
    // iteration means the second request is never admitted — it must
    // still appear in the report rather than silently vanish
    let mut engine = Engine::with_clusters(1);
    let a = engine.submit_request(Request::new(0, tiny_gpt2(64)).with_tokens(5));
    let b = engine.submit_request(Request::new(0, tiny_gpt2(64)).with_tokens(5));
    let mut backend = AnalyticBackend::new();
    let report = engine.serve(&mut backend, None, &ServeOptions::legacy(1));
    report.assert_consistent();
    assert_eq!(report.iterations, 1);
    assert_eq!(report.per_request.len(), 2, "both requests reported");
    let ra = report.per_request.iter().find(|r| r.request_id == a).unwrap();
    let rb = report.per_request.iter().find(|r| r.request_id == b).unwrap();
    assert_eq!(ra.tokens, 1, "admitted request prefilled");
    assert_eq!(rb.tokens, 0, "never-admitted request has zero progress");
    assert_eq!(rb.cycles, 0.0);
}

#[test]
fn arrival_gaps_fast_forward_without_counting_iterations() {
    let mut engine = Engine::new();
    engine.submit_request(Request::new(0, tiny_gpt2(64)).with_tokens(1).arriving_at(100));
    let mut backend = AnalyticBackend::new();
    let report = engine.serve(&mut backend, None, &ServeOptions::default());
    report.assert_consistent();
    assert_eq!(report.iterations, 1, "only the prefill iteration executed");
    assert_eq!(report.per_request.len(), 1);
    assert_eq!(report.per_request[0].tokens, 1);
    assert_eq!(report.log.len(), 1);
    assert_eq!(report.log[0].iter, 100, "scheduled at its arrival index");
}
