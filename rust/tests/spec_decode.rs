//! Integration wall for speculative decoding + chunked prefill
//! (DESIGN.md §15), served through the unified `Engine::serve` API.
//!
//! Four layers of evidence, mirroring the differential style of the
//! paged-KV wall:
//!
//! 1. **Cross-path bit-identity** — a speculative run and a chunked run
//!    are pure functions of (trace, options): the decoded fast path and
//!    the reference interpreter must agree bit-exactly on cycles, SPM
//!    bytes, and every per-request book.
//! 2. **Reduction guarantees** — `k = 0` and an effectively unbounded
//!    chunk size reduce bit-identically to the plain serve loop on both
//!    simulator paths; only the chunk *counter* may differ.
//! 3. **Seeded acceptance model** — the token books of a speculative
//!    run match a plain run for any (k, seed, accept), and the
//!    acceptance extremes (`accept` 0 and 1) pin the draft/accept
//!    counters exactly.
//! 4. **Fork lifecycle under the paged tier** — pool books balance
//!    across fork / commit / rollback under random acceptance and real
//!    memory pressure, and fork-side copy-on-write is actually
//!    exercised and counted.
//!
//! Plus the serving-shape claims: chunked prefill strictly improves a
//! co-scheduled short request's TTFT, and the {GPT-2, GPT-3, ViT} x
//! {plain, speculative, chunked} scenario matrix completes.

use vexp::exec::{
    AnalyticBackend, CycleSimBackend, Engine, Outcome, PagedKvOptions, Request, ServeOptions,
    ServeReport, SpecDecodeOptions, TraceSpec,
};
use vexp::model::{GPT2_SMALL, GPT3_XL, VIT_BASE};
use vexp::sim::spm_checksum;
use vexp::testkit::forall;

// ---------------------------------------------------------------------------
// shared drivers
// ---------------------------------------------------------------------------

/// Serve the standard mixed burst trace on the cycle simulator with the
/// given options, returning the report plus every cluster's SPM
/// checksum. The run is a pure function of (trace, options, path), so
/// two calls with the same arguments must agree bit-exactly.
fn serve_mixed_trace(
    opts: impl Fn(ServeOptions) -> ServeOptions,
    reference: bool,
) -> (ServeReport, Vec<u64>) {
    let spec = TraceSpec::bursty(6, 40_000.0, 5);
    let mut engine = Engine::with_clusters(4);
    for r in spec.mixed_traffic(32, 4, None) {
        engine.submit_request(r);
    }
    let mut backend = CycleSimBackend::new(4);
    backend.system.reference_interp = reference;
    let opts = opts(ServeOptions::new().max_iters(256));
    let report = engine.serve(&mut backend, None, &opts);
    report.assert_consistent();
    let sums = backend.system.clusters.iter().map(|c| spm_checksum(&c.spm)).collect();
    (report, sums)
}

/// Assert two serve reports of the same trace are bit-identical in
/// every field the §15 contract covers (cycle books, energy, token
/// books, speculative books, chunk books).
fn assert_reports_bit_identical(a: &ServeReport, b: &ServeReport, what: &str) {
    assert_eq!(a.iterations, b.iterations, "{what}: iteration count");
    assert_eq!(a.total_cycles, b.total_cycles, "{what}: total cycles");
    assert_eq!(a.per_request.len(), b.per_request.len(), "{what}: request count");
    for (x, y) in a.per_request.iter().zip(&b.per_request) {
        let id = x.request_id;
        assert_eq!(x.request_id, y.request_id, "{what}: request order");
        assert_eq!(x.outcome, y.outcome, "{what}: request {id} outcome");
        assert_eq!(x.tokens, y.tokens, "{what}: request {id} tokens");
        assert_eq!(
            x.cycles.to_bits(),
            y.cycles.to_bits(),
            "{what}: request {id} cycles diverged bitwise"
        );
        assert_eq!(
            x.ttft_cycles.to_bits(),
            y.ttft_cycles.to_bits(),
            "{what}: request {id} TTFT diverged bitwise"
        );
        assert_eq!(
            x.energy_pj.to_bits(),
            y.energy_pj.to_bits(),
            "{what}: request {id} energy diverged bitwise"
        );
        assert_eq!(
            (x.spec_rounds, x.drafted_tokens, x.accepted_tokens),
            (y.spec_rounds, y.drafted_tokens, y.accepted_tokens),
            "{what}: request {id} speculative books"
        );
        assert_eq!(
            x.draft_cycles.to_bits(),
            y.draft_cycles.to_bits(),
            "{what}: request {id} draft cycles diverged bitwise"
        );
        assert_eq!(
            x.verify_cycles.to_bits(),
            y.verify_cycles.to_bits(),
            "{what}: request {id} verify cycles diverged bitwise"
        );
        assert_eq!(x.prefill_chunks, y.prefill_chunks, "{what}: request {id} chunk books");
    }
}

// ---------------------------------------------------------------------------
// 1. cross-path bit-identity
// ---------------------------------------------------------------------------

/// Acceptance draws come from the seeded model, not the backend, so a
/// speculative run must be bit-identical between the decoded fast path
/// and the reference interpreter — cycles, SPM bytes, and books alike —
/// while actually drafting and verifying real tokens.
#[test]
fn speculative_serve_is_bit_identical_across_sim_paths() {
    let with_spec =
        |o: ServeOptions| o.speculative(SpecDecodeOptions::new(GPT2_SMALL, 3).seed(21));
    let (fast, fast_sums) = serve_mixed_trace(with_spec, false);
    let (refr, ref_sums) = serve_mixed_trace(with_spec, true);

    assert_reports_bit_identical(&fast, &refr, "speculative fast-vs-reference");
    assert_eq!(fast_sums, ref_sums, "SPM bytes diverged between simulator paths");

    // real speculation happened on this trace
    let d = &fast.decode;
    assert!(d.spec_rounds > 0, "trace must run speculative rounds");
    assert!(d.drafted_tokens > 0, "rounds must draft tokens");
    assert!(d.accepted_tokens <= d.drafted_tokens);
    assert!(d.draft_cycles > 0.0, "draft sub-iterations must cost cycles");
    assert!(d.verify_cycles > 0.0, "verify passes must cost cycles");
    // only decode-bearing GPT-2 requests are eligible; ViT never drafts
    for r in &fast.per_request {
        if r.model == "ViT-Base" {
            assert_eq!(r.drafted_tokens, 0, "prefill-only requests must not speculate");
        }
        assert_eq!(r.outcome, Outcome::Completed, "request {}", r.request_id);
    }
}

/// Chunked prefill reshapes iterations but stays a pure function of the
/// options: both simulator paths agree bit-exactly, and long prompts
/// really do split into multiple chunks.
#[test]
fn chunked_prefill_is_bit_identical_across_sim_paths() {
    let with_chunks = |o: ServeOptions| o.chunked_prefill(8);
    let (fast, fast_sums) = serve_mixed_trace(with_chunks, false);
    let (refr, ref_sums) = serve_mixed_trace(with_chunks, true);

    assert_reports_bit_identical(&fast, &refr, "chunked fast-vs-reference");
    assert_eq!(fast_sums, ref_sums, "SPM bytes diverged between simulator paths");

    let d = &fast.decode;
    assert!(d.chunked_requests > 0, "32/64-token prompts must split at chunk 8");
    assert!(
        d.prefill_chunks > fast.per_request.len() as u64,
        "chunking must add chunks beyond one-per-request ({} chunks)",
        d.prefill_chunks
    );
    for r in &fast.per_request {
        assert_eq!(r.outcome, Outcome::Completed, "request {}", r.request_id);
        assert!(r.prefill_chunks >= 1, "every prefilled request books >= 1 chunk");
    }
}

// ---------------------------------------------------------------------------
// 2. reduction guarantees: k = 0 and chunk = infinity are plain serving
// ---------------------------------------------------------------------------

/// `k = 0` must change nothing at all, and a chunk size larger than any
/// prompt must change nothing but the chunk counter — bit-for-bit, on
/// both simulator paths. The plain loop is the differential oracle.
#[test]
fn spec_k0_and_giant_chunk_reduce_bitwise_to_plain_on_both_sim_paths() {
    for reference in [false, true] {
        let (plain, plain_sums) = serve_mixed_trace(|o| o, reference);
        let (k0, k0_sums) = serve_mixed_trace(
            |o| o.speculative(SpecDecodeOptions::new(GPT2_SMALL, 0).seed(21)),
            reference,
        );
        let (giant, giant_sums) = serve_mixed_trace(|o| o.chunked_prefill(1 << 20), reference);

        assert_reports_bit_identical(&plain, &k0, "k=0 vs plain");
        assert_eq!(plain_sums, k0_sums, "k=0 SPM bytes (reference_interp={reference})");
        assert_eq!(k0.decode.spec_rounds, 0, "k=0 must never open a round");
        assert_eq!(k0.decode.drafted_tokens, 0);

        // the giant-chunk run books exactly one chunk per prefilled
        // request; everything else is bitwise plain
        assert_eq!(plain.iterations, giant.iterations, "giant-chunk iterations");
        assert_eq!(plain.total_cycles, giant.total_cycles, "giant-chunk total cycles");
        assert_eq!(plain_sums, giant_sums, "giant-chunk SPM (reference_interp={reference})");
        for (p, g) in plain.per_request.iter().zip(&giant.per_request) {
            let id = p.request_id;
            assert_eq!(p.outcome, g.outcome, "request {id} outcome");
            assert_eq!(p.tokens, g.tokens, "request {id} tokens");
            assert_eq!(p.cycles.to_bits(), g.cycles.to_bits(), "request {id} cycles");
            assert_eq!(p.ttft_cycles.to_bits(), g.ttft_cycles.to_bits(), "request {id} TTFT");
            assert_eq!(p.energy_pj.to_bits(), g.energy_pj.to_bits(), "request {id} energy");
            assert_eq!(g.prefill_chunks, 1, "request {id}: one unsplit chunk");
        }
        assert_eq!(giant.decode.chunked_requests, 0, "nothing actually split");
    }
}

// ---------------------------------------------------------------------------
// 3. the seeded acceptance model
// ---------------------------------------------------------------------------

/// Property: for any (k, seed, accept), speculation is an execution
/// strategy, not a semantics change — every request ends with exactly
/// the token books of a plain run.
#[test]
fn speculative_token_books_match_plain_for_any_k_seed_accept() {
    forall(12, |rng| {
        let k = rng.range(1, 6) as u32;
        let seed = rng.next_u64();
        let accept = rng.f64(0.0, 1.0);
        let tokens = rng.range(2, 11) as u32;

        let run = |spec: Option<SpecDecodeOptions>| -> ServeReport {
            let mut engine = Engine::with_clusters(4);
            for i in 0..3u64 {
                let mut cfg = GPT2_SMALL;
                cfg.seq = 16;
                engine.submit_request(Request::new(i, cfg).with_tokens(tokens));
            }
            let mut backend = AnalyticBackend::new();
            let mut opts = ServeOptions::new().max_iters(512);
            if let Some(s) = spec {
                opts = opts.speculative(s);
            }
            let report = engine.serve(&mut backend, None, &opts);
            report.assert_consistent();
            report
        };

        let plain = run(None);
        let spec = run(Some(SpecDecodeOptions::new(GPT2_SMALL, k).seed(seed).accept(accept)));

        if plain.per_request.len() != spec.per_request.len() {
            return Err("request counts diverged".into());
        }
        for (p, s) in plain.per_request.iter().zip(&spec.per_request) {
            let books =
                |r: &vexp::exec::RunReport| (r.request_id, r.tokens, r.token_target, r.outcome);
            if books(p) != books(s) {
                return Err(format!(
                    "token books diverged (k={k} accept={accept:.2}): {:?} vs {:?}",
                    books(p),
                    books(s)
                ));
            }
            if s.outcome != Outcome::Completed {
                return Err(format!("request {} did not complete", s.request_id));
            }
        }
        Ok(())
    });
}

/// The acceptance extremes pin the books exactly: `accept(1.0)` commits
/// every draft (the drafted and accepted counters coincide), and
/// `accept(0.0)` rejects every draft (rounds still run, nothing is
/// accepted) — both still completing every request.
#[test]
fn acceptance_extremes_pin_the_draft_books() {
    let run = |accept: f64| -> ServeReport {
        let mut engine = Engine::with_clusters(4);
        for i in 0..2u64 {
            let mut cfg = GPT2_SMALL;
            cfg.seq = 16;
            engine.submit_request(Request::new(i, cfg).with_tokens(9));
        }
        let mut backend = AnalyticBackend::new();
        let opts = ServeOptions::new()
            .max_iters(256)
            .speculative(SpecDecodeOptions::new(GPT2_SMALL, 3).seed(7).accept(accept));
        let report = engine.serve(&mut backend, None, &opts);
        report.assert_consistent();
        report
    };

    let all = run(1.0);
    assert!(all.decode.drafted_tokens > 0, "accept=1 must draft");
    assert_eq!(
        all.decode.accepted_tokens, all.decode.drafted_tokens,
        "accept=1 must commit every draft"
    );
    assert_eq!(all.decode.acceptance_rate, 1.0);

    let none = run(0.0);
    assert!(none.decode.spec_rounds > 0, "accept=0 still runs rounds");
    assert!(none.decode.drafted_tokens > 0, "accept=0 still drafts");
    assert_eq!(none.decode.accepted_tokens, 0, "accept=0 must reject every draft");
    assert_eq!(none.decode.acceptance_rate, 0.0);

    // rejection costs strictly more rounds per token than full
    // acceptance on the same trace
    assert!(none.decode.spec_rounds > all.decode.spec_rounds);
    for r in all.per_request.iter().chain(&none.per_request) {
        assert_eq!(r.outcome, Outcome::Completed, "request {}", r.request_id);
        assert_eq!(r.tokens, 9, "speculation must not change the token count");
    }
}

// ---------------------------------------------------------------------------
// 4. fork lifecycle on the paged tier
// ---------------------------------------------------------------------------

/// Property: under a tight pool — where draft forks, their appends,
/// commits, rollbacks, preemptions, and done-releases all compete for
/// the same 14 blocks — the pool books must balance after every run,
/// for any (k, seed, accept), and every request must still complete
/// with its full token target.
#[test]
fn pool_books_balance_across_fork_commit_rollback() {
    forall(10, |rng| {
        let k = rng.range(1, 5) as u32;
        let seed = rng.next_u64();
        let accept = rng.f64(0.0, 1.0);

        let mut engine = Engine::with_clusters(4);
        for i in 0..4u64 {
            let mut cfg = GPT2_SMALL;
            cfg.seq = 8;
            engine.submit_request(Request::new(i, cfg).with_tokens(12));
        }
        let mut backend = AnalyticBackend::new();
        // GPT-2 Small KV is 36 864 B/token: a 128 KiB block holds 3
        // tokens; 14 blocks fit any one lifetime but not four at once.
        let opts = ServeOptions::new()
            .max_iters(2048)
            .paging(PagedKvOptions {
                block_bytes: 128 * 1024,
                pool_bytes: 14 * 128 * 1024,
                share_prefix: false,
            })
            .speculative(SpecDecodeOptions::new(GPT2_SMALL, k).seed(seed).accept(accept));
        let report = engine.serve(&mut backend, None, &opts);
        report.assert_consistent(); // includes allocated == freed + resident

        let pool = report.pool.as_ref().ok_or("paged run must carry a pool report")?;
        if pool.allocated != pool.freed {
            return Err(format!(
                "lifetime books unbalanced after retirement: {} allocated vs {} freed",
                pool.allocated, pool.freed
            ));
        }
        if report.decode.spec_rounds == 0 {
            return Err("tight-pool run must still open speculative rounds".into());
        }
        for r in &report.per_request {
            if r.outcome != Outcome::Completed || r.tokens != 12 {
                return Err(format!(
                    "request {} ended {:?} with {} of 12 tokens",
                    r.request_id, r.outcome, r.tokens
                ));
            }
        }
        Ok(())
    });
}

/// A draft fork shares its target's partially-filled tail block, so the
/// fork's very first append must copy-on-write — the counter the
/// unpaged-equivalence test pins to zero must go strictly positive the
/// moment speculation is on.
#[test]
fn draft_forks_exercise_copy_on_write_on_shared_tails() {
    let mut engine = Engine::with_clusters(4);
    for i in 0..2u64 {
        let mut cfg = GPT2_SMALL;
        cfg.seq = 8;
        engine.submit_request(Request::new(i, cfg).with_tokens(6));
    }
    let mut backend = AnalyticBackend::new();
    let opts = ServeOptions::new()
        .max_iters(256)
        .paging(PagedKvOptions::unbounded())
        .speculative(SpecDecodeOptions::new(GPT2_SMALL, 2).seed(3));
    let report = engine.serve(&mut backend, None, &opts);
    report.assert_consistent();

    let pool = report.pool.as_ref().expect("paged run must carry a pool report");
    assert!(report.decode.spec_rounds > 0, "speculation must run");
    assert!(
        pool.cow_copies > 0,
        "a fork's first append into the shared tail must copy-on-write"
    );
    assert_eq!(pool.preemptions, 0, "an unbounded pool never preempts");
    assert_eq!(pool.allocated, pool.freed, "fork blocks must all be released");
    for r in &report.per_request {
        assert_eq!(r.outcome, Outcome::Completed, "request {}", r.request_id);
        assert_eq!(r.tokens, 6);
    }
}

// ---------------------------------------------------------------------------
// serving-shape claims
// ---------------------------------------------------------------------------

/// The point of chunked prefill: a short request co-scheduled with a
/// long prompt no longer waits out the long prompt's monolithic prefill
/// iteration, so its TTFT must strictly improve versus the plain loop.
#[test]
fn chunked_prefill_improves_cosched_short_request_ttft() {
    let run = |chunk: Option<u32>| -> ServeReport {
        let mut engine = Engine::with_clusters(4);
        let mut long = GPT2_SMALL;
        long.seq = 512;
        let mut short = GPT2_SMALL;
        short.seq = 16;
        engine.submit_request(Request::new(0, long).with_tokens(4));
        engine.submit_request(Request::new(1, short).with_tokens(4));
        let mut backend = AnalyticBackend::new();
        let mut opts = ServeOptions::new().max_iters(512);
        if let Some(c) = chunk {
            opts = opts.chunked_prefill(c);
        }
        let report = engine.serve(&mut backend, None, &opts);
        report.assert_consistent();
        report
    };

    let plain = run(None);
    let chunked = run(Some(32));

    let ttft = |report: &ServeReport, id: u64| {
        report
            .per_request
            .iter()
            .find(|r| r.request_id == id)
            .expect("request in report")
            .ttft_cycles
    };
    // the short request's own prefill fits one chunk either way; only
    // the iteration barrier around it changes
    assert!(
        ttft(&chunked, 1) < ttft(&plain, 1),
        "chunking must shrink the short request's TTFT: {} !< {}",
        ttft(&chunked, 1),
        ttft(&plain, 1)
    );
    // the long prompt really ran chunked: 512 tokens at chunk 32
    let long = chunked.per_request.iter().find(|r| r.request_id == 0).unwrap();
    assert_eq!(long.prefill_chunks, 16, "512-token prompt at chunk 32");
    for r in plain.per_request.iter().chain(&chunked.per_request) {
        assert_eq!(r.outcome, Outcome::Completed, "request {}", r.request_id);
    }
}

/// The acceptance-criterion matrix: {GPT-2, GPT-3, ViT} x {plain,
/// speculative, chunked} all complete under the one `Engine::serve`
/// entry point, with the expected books in each cell.
#[test]
fn scenario_matrix_completes_under_unified_serve() {
    for (model_name, model) in [("gpt2", GPT2_SMALL), ("gpt3", GPT3_XL), ("vit", VIT_BASE)] {
        for scenario in ["plain", "speculative", "chunked"] {
            let mut engine = Engine::with_clusters(4);
            for i in 0..2u64 {
                let mut cfg = model;
                cfg.seq = 64.min(cfg.seq);
                let tokens = if model_name == "vit" { 0 } else { 5 };
                engine.submit_request(Request::new(i, cfg).with_tokens(tokens));
            }
            let mut backend = AnalyticBackend::new();
            let opts = match scenario {
                "speculative" => ServeOptions::new()
                    .max_iters(256)
                    .speculative(SpecDecodeOptions::new(GPT2_SMALL, 3).seed(15)),
                "chunked" => ServeOptions::new().max_iters(256).chunked_prefill(16),
                _ => ServeOptions::new().max_iters(256),
            };
            let report = engine.serve(&mut backend, None, &opts);
            report.assert_consistent();

            for r in &report.per_request {
                assert_eq!(
                    r.outcome,
                    Outcome::Completed,
                    "{model_name}/{scenario}: request {}",
                    r.request_id
                );
            }
            match scenario {
                "speculative" if model_name != "vit" => assert!(
                    report.decode.drafted_tokens > 0,
                    "{model_name}/speculative must draft"
                ),
                "speculative" => assert_eq!(
                    report.decode.drafted_tokens, 0,
                    "prefill-only ViT must not draft"
                ),
                "chunked" => assert!(
                    report.decode.prefill_chunks >= report.per_request.len() as u64,
                    "{model_name}/chunked books at least one chunk per request"
                ),
                _ => {
                    assert_eq!(report.decode.spec_rounds, 0);
                    assert_eq!(report.decode.prefill_chunks, 0);
                }
            }
        }
    }
}
