//! Integration: the PJRT runtime executes the AOT artifacts and the
//! numerics agree with the Layer-3 models (Python never runs here).
//!
//! Requires the `pjrt` cargo feature (XLA bindings) plus the artifacts
//! from `make artifacts`; without the feature this test target is empty.
#![cfg(feature = "pjrt")]

use vexp::bf16::Bf16;
use vexp::runtime::pjrt::Input;
use vexp::runtime::Runtime;
use vexp::vexp::exp_unit;

fn runtime() -> Runtime {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Runtime::open(dir).expect("run `make artifacts` first")
}

#[test]
fn manifest_lists_all_entry_points() {
    let rt = runtime();
    let eps = rt.entry_points();
    for want in [
        "vexp", "softmax_vexp", "softmax_exact", "fa2_vexp", "fa2_exact",
        "gpt_tiny_vexp", "gpt_tiny_fp32", "gpt_tiny_vexp_b8",
    ] {
        assert!(eps.contains(&want), "missing entry point {want}");
    }
}

#[test]
fn vexp_artifact_is_bit_identical_to_rust_model() {
    let mut rt = runtime();
    // 4096 inputs spanning the interesting range
    let xs: Vec<f32> = (0..4096).map(|i| (i as f32 - 2048.0) * 0.04).collect();
    let out = rt.execute("vexp", &[Input::F32(&xs)]).unwrap();
    for (i, &x) in xs.iter().enumerate() {
        let want = exp_unit(Bf16::from_f32(x)).to_f32();
        assert_eq!(out[i], want, "x = {x}: pjrt {} vs rust {want}", out[i]);
    }
}

#[test]
fn softmax_artifact_rows_sum_to_one() {
    let mut rt = runtime();
    let x: Vec<f32> = (0..64 * 512).map(|i| ((i % 113) as f32) * 0.12 - 6.0).collect();
    let out = rt.execute("softmax_vexp", &[Input::F32(&x)]).unwrap();
    assert_eq!(out.len(), 64 * 512);
    for r in 0..64 {
        let s: f32 = out[r * 512..(r + 1) * 512].iter().sum();
        assert!((s - 1.0).abs() < 0.02, "row {r} sums to {s}");
    }
}

#[test]
fn softmax_vexp_close_to_exact_artifact() {
    let mut rt = runtime();
    let x: Vec<f32> = (0..64 * 512).map(|i| ((i % 89) as f32) * 0.1 - 4.0).collect();
    let a = rt.execute("softmax_vexp", &[Input::F32(&x)]).unwrap();
    let b = rt.execute("softmax_exact", &[Input::F32(&x)]).unwrap();
    let max_err = a
        .iter()
        .zip(&b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 0.01, "vexp vs exact softmax max err {max_err}");
}

#[test]
fn fa2_artifact_matches_exact_variant() {
    let mut rt = runtime();
    let q: Vec<f32> = (0..128 * 64).map(|i| ((i % 37) as f32 - 18.0) * 0.05).collect();
    let k: Vec<f32> = (0..256 * 64).map(|i| ((i % 41) as f32 - 20.0) * 0.05).collect();
    let v: Vec<f32> = (0..256 * 64).map(|i| ((i % 43) as f32 - 21.0) * 0.05).collect();
    let ins = [Input::F32(&q), Input::F32(&k), Input::F32(&v)];
    let a = rt.execute("fa2_vexp", &ins).unwrap();
    let ins2 = [Input::F32(&q), Input::F32(&k), Input::F32(&v)];
    let b = rt.execute("fa2_exact", &ins2).unwrap();
    assert_eq!(a.len(), 128 * 64);
    let max_err = a
        .iter()
        .zip(&b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 0.05, "fa2 vexp vs exact max err {max_err}");
}

#[test]
fn unknown_entry_point_errors_cleanly() {
    let mut rt = runtime();
    assert!(rt.execute("nonexistent", &[]).is_err());
}

#[test]
fn wrong_arity_errors_cleanly() {
    let mut rt = runtime();
    let x = vec![0.0f32; 4096];
    assert!(rt
        .execute("fa2_vexp", &[Input::F32(&x)])
        .is_err());
}

#[test]
fn gpt_tiny_artifact_serves_finite_logits() {
    // the e2e model artifact: tokens (1,128) i32 + theta (10.7M) f32
    let mut rt = runtime();
    let art = rt.artifact("gpt_tiny_vexp").unwrap().clone();
    let n_theta: usize = art.inputs[1].0.iter().product();
    let dir = rt.artifact_dir().to_path_buf();
    let theta_path = ["theta.bin", "theta_random.bin"]
        .iter()
        .map(|f| dir.join(f))
        .find(|p| p.exists())
        .expect("theta artifact missing");
    let bytes = std::fs::read(theta_path).unwrap();
    let theta: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    assert_eq!(theta.len(), n_theta);
    let tokens: Vec<i32> = (0..128).map(|i| (i * 7) % 64).collect();
    let logits = rt
        .execute("gpt_tiny_vexp", &[Input::I32(&tokens), Input::F32(&theta)])
        .unwrap();
    assert_eq!(logits.len(), 128 * 64);
    assert!(logits.iter().all(|x| x.is_finite()));
    // logits must discriminate (not constant)
    let (lo, hi) = logits.iter().fold((f32::MAX, f32::MIN), |(l, h), &x| (l.min(x), h.max(x)));
    assert!(hi - lo > 1.0, "degenerate logits [{lo}, {hi}]");
}
