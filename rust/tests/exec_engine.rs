//! Integration tests for the unified execution engine: cross-backend
//! agreement, program-cache behavior, and batched multi-request serving
//! on the 16-cluster system.

use vexp::coordinator::{TilePlan, CLUSTERS};
use vexp::exec::{
    AnalyticBackend, Backend, CycleSimBackend, Engine, KernelKind, ProgramCache, ProgramKey,
    Request,
};
use vexp::kernels::softmax::{build_softmax_program, SoftmaxVariant};
use vexp::model::{GPT2_SMALL, GPT3_XL, VIT_BASE, VIT_HUGE};

const ALL: [vexp::model::TransformerConfig; 4] = [GPT2_SMALL, GPT3_XL, VIT_BASE, VIT_HUGE];

fn ratio(a: f64, b: f64) -> f64 {
    assert!(a > 0.0 && b > 0.0, "non-positive cycle counts: {a} vs {b}");
    a / b
}

/// The two backends obtain their kernel rates independently — the
/// analytic backend from fixed-shape calibration, the cycle-sim backend
/// by running the request's own kernels — so agreement is a real
/// cross-check, not an identity. Tolerance bands: softmax rates differ
/// only by row-length amortization; the FlashAttention scope also
/// carries the real kernel's tiling overhead (stats updates, rescale,
/// final norm), so its band is wider.
#[test]
fn backends_agree_on_softmax_and_flashattention_cycles() {
    let mut analytic = AnalyticBackend::new();
    let mut cyclesim = CycleSimBackend::new(CLUSTERS);
    for (i, cfg) in ALL.iter().enumerate() {
        let req = Request::new(i as u64, *cfg);
        let a = analytic.estimate(&req);
        let c = cyclesim.estimate(&req);
        assert_eq!(a.backend, "analytic");
        assert_eq!(c.backend, "cycle-sim");

        let sm = ratio(a.softmax_cycles, c.softmax_cycles);
        assert!(
            (0.5..=2.0).contains(&sm),
            "{}: softmax cycles disagree: analytic {:.3e} vs cycle-sim {:.3e} (ratio {sm:.2})",
            cfg.name,
            a.softmax_cycles,
            c.softmax_cycles
        );

        let fa = ratio(a.attn_cycles, c.attn_cycles);
        assert!(
            (0.25..=4.0).contains(&fa),
            "{}: FlashAttention cycles disagree: analytic {:.3e} vs cycle-sim {:.3e} (ratio {fa:.2})",
            cfg.name,
            a.attn_cycles,
            c.attn_cycles
        );

        let total = ratio(a.cycles, c.cycles);
        assert!(
            (0.25..=4.0).contains(&total),
            "{}: total cycles disagree: ratio {total:.2}",
            cfg.name
        );
    }
}

/// Repeated estimates for the same model shape must hit the cycle-sim
/// backend's calibration-program cache instead of re-running builders.
#[test]
fn cyclesim_estimates_reuse_calibration_programs() {
    let mut cyclesim = CycleSimBackend::new(CLUSTERS);
    let req = Request::new(0, GPT2_SMALL);
    cyclesim.estimate(&req);
    let misses_after_first = cyclesim.cache.misses;
    assert!(misses_after_first >= 3, "softmax + gemm + FA programs compiled");
    cyclesim.estimate(&req);
    assert_eq!(
        cyclesim.cache.misses, misses_after_first,
        "second estimate must not compile anything new"
    );
    assert!(cyclesim.cache.hits >= 3);
}

/// A cache hit returns the identical instruction stream (shared
/// storage) without re-running the kernel builder.
#[test]
fn program_cache_hit_returns_identical_stream() {
    let mut cache = ProgramCache::new();
    let key = ProgramKey::for_kernel(
        KernelKind::Softmax(SoftmaxVariant::SwExpHw),
        [8, 256, 0, 0, 0, 0],
        8,
    );
    let mut builder_runs = 0u32;
    let first = cache.get_or_build(key, || {
        builder_runs += 1;
        build_softmax_program(SoftmaxVariant::SwExpHw, 8, 256)
    });
    let second = cache.get_or_build(key, || {
        builder_runs += 1;
        build_softmax_program(SoftmaxVariant::SwExpHw, 8, 256)
    });
    assert_eq!(builder_runs, 1, "cache hit must not re-run the builder");
    assert!(first.shares_storage_with(&second), "hit must return the same stream");
    assert_eq!(first.instr_count(), second.instr_count());
    assert_eq!((cache.hits, cache.misses), (1, 1));
}

/// Serve four mixed-model concurrent requests (different sequence
/// lengths included) on the 16-cluster system: every request gets its
/// own RunReport from real simulation, and the duplicated model shape
/// produces a measured cache hit in the batched path.
#[test]
fn batched_serving_reports_per_request_with_cache_hits() {
    let mut short_gpt3 = GPT3_XL;
    short_gpt3.seq = 256; // mixed sequence lengths in one batch
    let mix = [VIT_BASE, VIT_BASE, GPT2_SMALL, short_gpt3];

    let mut engine = Engine::new();
    for cfg in mix {
        engine.submit(cfg);
    }
    let batch = engine.compile_batch();
    assert_eq!(batch.requests.len(), 4);
    assert!(
        batch.cache_hits >= 1,
        "duplicate ViT-Base must hit the program cache (hits {})",
        batch.cache_hits
    );

    // disjoint cluster ownership across the 16 clusters
    let mut owned = vec![false; CLUSTERS];
    for cr in &batch.requests {
        assert!(!cr.clusters.is_empty());
        for &c in &cr.clusters {
            assert!(!owned[c], "cluster {c} double-assigned");
            owned[c] = true;
        }
    }

    let mut sim = CycleSimBackend::new(CLUSTERS);
    let report = sim.execute(&batch);
    assert_eq!(report.per_request.len(), 4);
    assert_eq!(report.cache_hits, batch.cache_hits);
    for (cr, r) in batch.requests.iter().zip(&report.per_request) {
        assert_eq!(r.request_id, cr.req.id);
        assert_eq!(r.model, cr.req.cfg.name);
        assert!(r.cycles > 0.0, "{}: no measured cycles", r.model);
        assert!(r.energy_pj > 0.0);
        assert_eq!(r.clusters_used, cr.clusters.len());
        assert_eq!(r.per_cluster.len(), cr.clusters.len());
        assert!(
            r.cycles as u64 <= report.makespan_cycles,
            "{}: request exceeds batch makespan",
            r.model
        );
        // real simulation evidence: retired instructions on every
        // cluster the request owns
        for cs in &r.per_cluster {
            assert!(cs.combined().retired_total() > 0);
        }
    }
    assert!(report.hbm_bytes > 0);

    // the analytic backend rates the same batch within a loose band
    let mut analytic = AnalyticBackend::new();
    let rated = analytic.execute(&batch);
    assert_eq!(rated.per_request.len(), 4);
    for (m, a) in report.per_request.iter().zip(&rated.per_request) {
        let r = m.cycles / a.cycles;
        assert!(
            (0.2..=5.0).contains(&r),
            "{}: cycle-sim {:.0} vs analytic {:.0} (ratio {r:.2})",
            m.model,
            m.cycles,
            a.cycles
        );
    }
}

/// The engine facade: submit → execute_batch drains the queue and
/// reuses the cache across batches.
#[test]
fn engine_serves_consecutive_batches_through_one_cache() {
    let mut engine = Engine::new();
    let mut sim = CycleSimBackend::new(CLUSTERS);

    engine.submit(VIT_BASE);
    engine.submit(VIT_BASE);
    let first = engine.execute_batch(&mut sim);
    assert_eq!(first.per_request.len(), 2);
    assert_eq!(engine.pending(), 0);
    assert_eq!(first.cache_misses, 1);
    assert_eq!(first.cache_hits, 1);

    // a second batch of the same shape compiles nothing new
    engine.submit(VIT_BASE);
    let second = engine.execute_batch(&mut sim);
    assert_eq!(second.per_request.len(), 1);
    assert_eq!(second.cache_misses, 0);
    assert_eq!(second.cache_hits, 1);
}

/// Baseline-softmax requests must cost more than optimized ones on both
/// backends (the Fig. 8 direction), through the same unified API.
#[test]
fn backends_preserve_the_optimization_direction() {
    let mut analytic = AnalyticBackend::new();
    let mut cyclesim = CycleSimBackend::new(CLUSTERS);
    let base = Request::baseline(0, GPT2_SMALL);
    let opt = Request::new(1, GPT2_SMALL);
    for backend in [&mut analytic as &mut dyn Backend, &mut cyclesim] {
        let b = backend.estimate(&base);
        let o = backend.estimate(&opt);
        assert!(
            b.cycles > o.cycles,
            "{}: baseline {} !> optimized {}",
            backend.name(),
            b.cycles,
            o.cycles
        );
        assert!(
            b.softmax_share() > o.softmax_share(),
            "{}: softmax share must shrink when optimized",
            backend.name()
        );
    }
}

/// The over-budget tile-plan fix feeds the engine: wide-head configs
/// still produce simulable batches.
#[test]
fn wide_head_requests_are_schedulable() {
    let wide = vexp::model::TransformerConfig {
        name: "wide-head",
        layers: 2,
        d_model: 2048,
        heads: 8,
        d_ff: 4096,
        seq: 512,
    };
    let plan = TilePlan::plan(&wide);
    assert!(plan.bk < 64, "d_head 256 must shrink bk (got {})", plan.bk);
    let mut engine = Engine::new();
    engine.submit(wide);
    engine.submit(VIT_BASE);
    let batch = engine.compile_batch();
    let mut sim = CycleSimBackend::new(CLUSTERS);
    let report = sim.execute(&batch);
    assert_eq!(report.per_request.len(), 2);
    assert!(report.per_request.iter().all(|r| r.cycles > 0.0));
}
