//! The hardware-correctness invariant of this reproduction: the Rust
//! ExpUnit model and the Pallas kernel (via the AOT-dumped golden table)
//! are bit-identical over ALL 2^16 BF16 inputs.
//!
//! Requires `make artifacts` (the Makefile runs it before `cargo test`).

use std::path::PathBuf;
use vexp::bf16::Bf16;
use vexp::vexp::{exp_unit, fexp, vfexp};

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/vexp_golden.bin")
}

/// Load the AOT-dumped golden table; `None` (with a visible skip note)
/// when the artifacts have not been built in this environment.
fn load_golden() -> Option<Vec<u16>> {
    let bytes = match std::fs::read(golden_path()) {
        Ok(b) => b,
        Err(_) => {
            eprintln!(
                "SKIP: artifacts/vexp_golden.bin missing — run `make artifacts` \
                 to enable the exhaustive Pallas cross-check"
            );
            return None;
        }
    };
    assert_eq!(bytes.len(), 2 * 65536);
    Some(
        bytes
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes([c[0], c[1]]))
            .collect(),
    )
}

#[test]
fn rust_matches_pallas_exhaustively() {
    let Some(golden) = load_golden() else { return };
    let mut mismatches = 0usize;
    for bits in 0..=u16::MAX {
        let got = exp_unit(Bf16(bits)).0;
        let want = golden[bits as usize];
        if got != want {
            mismatches += 1;
            if mismatches <= 10 {
                eprintln!(
                    "bits {bits:#06x} (x={}): rust {got:#06x}, pallas {want:#06x}",
                    Bf16(bits).to_f32()
                );
            }
        }
    }
    assert_eq!(mismatches, 0, "{mismatches} / 65536 bit patterns differ");
}

#[test]
fn simd_lanes_match_golden_lanewise() {
    let Some(golden) = load_golden() else { return };
    // pack pseudo-random lane combinations and check each lane
    let mut state = 0x1234_5678_9ABC_DEF0u64;
    for _ in 0..10_000 {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let packed = state;
        let out = vfexp(packed);
        for lane in 0..4 {
            let in_bits = ((packed >> (16 * lane)) & 0xFFFF) as u16;
            let out_bits = ((out >> (16 * lane)) & 0xFFFF) as u16;
            assert_eq!(out_bits, golden[in_bits as usize], "lane {lane} of {packed:#018x}");
        }
    }
}

#[test]
fn scalar_fexp_matches_golden() {
    let Some(golden) = load_golden() else { return };
    for bits in (0..=u16::MAX).step_by(17) {
        assert_eq!(fexp(bits as u64) as u16, golden[bits as usize]);
    }
}
