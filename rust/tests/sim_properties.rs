//! Property tests over the simulator core (in-house testkit; proptest is
//! not in the offline crate cache).

use vexp::bf16::Bf16;
use vexp::isa::regs::*;
use vexp::isa::{Asm, Instr, SsrPattern};
use vexp::sim::{Core, Mem, SsrState, SsrStream};
use vexp::testkit::{forall, Rng};

fn write_random_row(spm: &mut Mem, base: u32, n: usize, rng: &mut Rng) -> Vec<f32> {
    let xs: Vec<f32> = (0..n).map(|_| rng.f32(-8.0, 8.0)).collect();
    spm.write_f32_as_bf16(base, &xs);
    xs
}

/// FREP must be functionally identical to the software-unrolled loop.
#[test]
fn frep_equals_unrolled() {
    forall(25, |rng| {
        let iters = rng.range(1, 65) as u32;
        // FREP version: accumulate `iters` beats through an SSR stream
        let mut spm1 = Mem::spm();
        write_random_row(&mut spm1, 0x1000, 4 * iters as usize, &mut rng.clone_for_data());
        let mut a = Asm::new();
        a.ssr_cfg(0, SsrPattern::read1d(0x1000, iters));
        a.ssr_enable();
        a.li(A1, iters as i64);
        a.frep(A1, 1);
        a.vfadd_h(FT3, FT3, FT0);
        a.ssr_disable();
        a.li(A0, 0x8000);
        a.fsd(FT3, A0, 0);
        let prog = a.finish();
        let mut c1 = Core::new();
        c1.run(&mut spm1, &prog);
        let frep_result = spm1.read_u64(0x8000);

        // unrolled version: explicit flds + vfadds
        let mut spm2 = Mem::spm();
        write_random_row(&mut spm2, 0x1000, 4 * iters as usize, &mut rng.clone_for_data());
        let mut b = Asm::new();
        b.li(A0, 0x1000);
        for i in 0..iters {
            b.fld(FT4, A0, 8 * i as i32);
            b.vfadd_h(FT3, FT3, FT4);
        }
        b.li(A0, 0x8000);
        b.fsd(FT3, A0, 0);
        let prog2 = b.finish();
        let mut c2 = Core::new();
        c2.run(&mut spm2, &prog2);
        let unrolled_result = spm2.read_u64(0x8000);

        if frep_result != unrolled_result {
            return Err(format!(
                "iters {iters}: frep {frep_result:#018x} != unrolled {unrolled_result:#018x}"
            ));
        }
        Ok(())
    });
}

/// Same program + same memory → identical cycles and results.
#[test]
fn execution_is_deterministic() {
    forall(20, |rng| {
        let n = rng.range(4, 64) as u32 * 4;
        let build = |spm: &mut Mem, rng: &mut Rng| {
            write_random_row(spm, 0x2000, n as usize, rng);
            let mut a = Asm::new();
            a.ssr_cfg(0, SsrPattern::read1d(0x2000, n / 4));
            a.ssr_cfg(1, SsrPattern::write1d(0x4000, n / 4));
            a.ssr_enable();
            a.li(A1, (n / 4) as i64);
            a.frep(A1, 1);
            a.vfexp_h(FT1, FT0);
            a.ssr_disable();
            a.finish()
        };
        let mut spm1 = Mem::spm();
        let p1 = build(&mut spm1, &mut rng.clone_for_data());
        let mut spm2 = Mem::spm();
        let p2 = build(&mut spm2, &mut rng.clone_for_data());
        let s1 = Core::new().run(&mut spm1, &p1);
        let s2 = Core::new().run(&mut spm2, &p2);
        if s1.cycles != s2.cycles || s1.retired_total() != s2.retired_total() {
            return Err("nondeterministic timing".into());
        }
        if spm1.read_bytes(0x4000, 2 * n as usize) != spm2.read_bytes(0x4000, 2 * n as usize) {
            return Err("nondeterministic results".into());
        }
        Ok(())
    });
}

/// The SIMD VFEXP path must agree with scalar FEXP element-by-element
/// for arbitrary packed inputs.
#[test]
fn vfexp_lanes_equal_scalar_fexp() {
    forall(50, |rng| {
        let lanes: Vec<f32> = (0..4).map(|_| rng.f32(-30.0, 30.0)).collect();
        let mut spm = Mem::spm();
        spm.write_f32_as_bf16(0x100, &lanes);
        let mut a = Asm::new();
        a.li(A0, 0x100);
        a.fld(FT3, A0, 0);
        a.vfexp_h(FT4, FT3);
        a.fsd(FT4, A0, 8);
        for i in 0..4 {
            a.flh(FT5, A0, 2 * i);
            a.fexp_h(FT6, FT5);
            a.fsh(FT6, A0, 16 + 2 * i);
        }
        let prog = a.finish();
        Core::new().run(&mut spm, &prog);
        for i in 0..4usize {
            let simd = spm.read_u16(0x108 + 2 * i as u32);
            let scalar = spm.read_u16(0x110 + 2 * i as u32);
            if simd != scalar {
                return Err(format!(
                    "lane {i} (x={}): simd {simd:#06x} != scalar {scalar:#06x}",
                    lanes[i]
                ));
            }
        }
        Ok(())
    });
}

/// Strided 2D SSR reads must visit exactly the configured addresses.
#[test]
fn ssr_2d_pattern_walks_rows() {
    forall(30, |rng| {
        let reps0 = rng.range(1, 9) as u32;
        let reps1 = rng.range(1, 9) as u32;
        let stride1 = 8 * rng.range(1, 9) as i32 * reps0 as i32;
        let mut spm = Mem::spm();
        // tag each beat with its (i1, i0) coordinates
        for i1 in 0..reps1 {
            for i0 in 0..reps0 {
                let addr = (0x2000 + i1 as i64 * stride1 as i64 + i0 as i64 * 8) as u32;
                spm.write_u64(addr, ((i1 as u64) << 32) | i0 as u64);
            }
        }
        let mut a = Asm::new();
        // value-preserving copy: max(x, -inf) pops the read stream once
        // per instruction (vfsgnj would pop twice — one per operand read)
        a.li(T0, 0xFF80_FF80_FF80_FF80u64 as i64);
        a.fmv_d_x(FT3, T0);
        a.ssr_cfg(0, SsrPattern::read2d(0x2000, 8, reps0, stride1, reps1));
        a.ssr_cfg(1, SsrPattern::write1d(0x8000, reps0 * reps1));
        a.ssr_enable();
        a.li(A1, (reps0 * reps1) as i64);
        a.frep(A1, 1);
        a.vfmax_h(FT1, FT0, FT3);
        a.ssr_disable();
        let prog = a.finish();
        Core::new().run(&mut spm, &prog);
        let mut k = 0u32;
        for i1 in 0..reps1 {
            for i0 in 0..reps0 {
                let got = spm.read_u64(0x8000 + 8 * k);
                let want = ((i1 as u64) << 32) | i0 as u64;
                // vfsgnj copies sign bits lane-wise: value-preserving copy
                if got != want {
                    return Err(format!("beat {k}: got {got:#x}, want {want:#x}"));
                }
                k += 1;
            }
        }
        Ok(())
    });
}

/// BF16 ops on the simulated FPU must match the host softfloat model.
#[test]
fn simulated_fpu_matches_host_bf16() {
    forall(60, |rng| {
        let x = rng.f32(-100.0, 100.0);
        let y = rng.f32(-100.0, 100.0);
        let mut spm = Mem::spm();
        spm.write_f32_as_bf16(0x100, &[x, y]);
        let mut a = Asm::new();
        a.li(A0, 0x100);
        a.flh(FT3, A0, 0);
        a.flh(FT4, A0, 2);
        a.fadd_h(FT5, FT3, FT4);
        a.fmul_h(FT6, FT3, FT4);
        a.fmax_h(FT7, FT3, FT4);
        a.fsh(FT5, A0, 4);
        a.fsh(FT6, A0, 6);
        a.fsh(FT7, A0, 8);
        let prog = a.finish();
        Core::new().run(&mut spm, &prog);
        let xb = Bf16::from_f32(x);
        let yb = Bf16::from_f32(y);
        let checks = [
            (spm.read_u16(0x104), xb.add(yb).0, "add"),
            (spm.read_u16(0x106), xb.mul(yb).0, "mul"),
            (spm.read_u16(0x108), xb.max(yb).0, "max"),
        ];
        for (got, want, op) in checks {
            if got != want {
                return Err(format!("{op}({x}, {y}): {got:#06x} != {want:#06x}"));
            }
        }
        Ok(())
    });
}

/// Draw a random 3D pattern with signed strides. `base` sits mid-range
/// so negative strides stay in (wrapped-u32) bounds the same way the
/// walker computes them.
fn random_pattern(rng: &mut Rng) -> SsrPattern {
    let stride = |rng: &mut Rng| -> i32 { 8 * (rng.range(0, 9) as i32 - 4) };
    SsrPattern {
        base: 0x10000 + 8 * rng.range(0, 64) as u32,
        stride0: stride(rng),
        reps0: rng.range(1, 6) as u32,
        stride1: stride(rng),
        reps1: rng.range(1, 6) as u32,
        stride2: stride(rng),
        reps2: rng.range(1, 6) as u32,
        write: rng.bool(),
    }
}

/// `SsrState::next_addr` must visit exactly the affine address sequence
/// in dimension order i0 (innermost) → i1 → i2, including negative
/// strides — the oracle the bulk flat-stream fast path is held to.
#[test]
fn ssr_next_addr_matches_affine_oracle() {
    forall(200, |rng| {
        let pat = random_pattern(rng);
        let mut st = SsrState::new(pat);
        for i2 in 0..pat.reps2 as i64 {
            for i1 in 0..pat.reps1 as i64 {
                for i0 in 0..pat.reps0 as i64 {
                    let want = (pat.base as i64
                        + i2 * pat.stride2 as i64
                        + i1 * pat.stride1 as i64
                        + i0 * pat.stride0 as i64) as u32;
                    let got = st.next_addr();
                    if got != want {
                        return Err(format!(
                            "pattern {pat:?} at ({i2},{i1},{i0}): got {got:#x}, want {want:#x}"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Negative strides walk backwards through each dimension.
#[test]
fn ssr_negative_strides_walk_backwards() {
    let pat = SsrPattern {
        base: 0x1000,
        stride0: -8,
        reps0: 3,
        stride1: -64,
        reps1: 2,
        stride2: 0,
        reps2: 1,
        write: false,
    };
    let mut st = SsrState::new(pat);
    let addrs: Vec<u32> = (0..6).map(|_| st.next_addr()).collect();
    assert_eq!(addrs, [0x1000, 0xFF8, 0xFF0, 0xFC0, 0xFB8, 0xFB0]);
}

/// Wrap order: i0 exhausts before i1 advances, i1 before i2.
#[test]
fn ssr_wrap_order_is_innermost_first() {
    let pat = SsrPattern::read3d(0, 1, 2, 100, 3, 10000, 2);
    let mut st = SsrState::new(pat);
    let addrs: Vec<u32> = (0..12).map(|_| st.next_addr()).collect();
    assert_eq!(
        addrs,
        [0, 1, 100, 101, 200, 201, 10000, 10001, 10100, 10101, 10200, 10201]
    );
}

/// One beat past the pattern must panic — both walkers, same message.
#[test]
#[should_panic(expected = "SSR stream exhausted")]
fn ssr_walker_panics_on_exhaustion() {
    let mut st = SsrState::new(SsrPattern::read2d(0x100, 8, 2, 16, 2));
    for _ in 0..4 {
        st.next_addr();
    }
    st.next_addr();
}

/// The decode-time stream (flat fast path or fallback walk) must agree
/// with the reference walker beat-for-beat on arbitrary patterns.
#[test]
fn ssr_stream_fast_path_matches_walker() {
    forall(200, |rng| {
        // mix arbitrary patterns with explicitly-contiguous ones so the
        // Flat arm is guaranteed coverage
        let pat = if rng.bool() {
            random_pattern(rng)
        } else {
            let n = rng.range(1, 9) as u32;
            let blocks = rng.range(1, 5) as u32;
            SsrPattern::read2d(0x2000, 8, n, 8 * n as i32, blocks)
        };
        let mut fast = SsrStream::new(pat);
        let mut slow = SsrState::new(pat);
        if fast.is_write() != pat.write {
            return Err("write flag diverges".into());
        }
        for k in 0..pat.beats() {
            let f = fast.next_addr();
            let s = slow.next_addr();
            if f != s {
                return Err(format!("pattern {pat:?} beat {k}: fast {f:#x} != walk {s:#x}"));
            }
        }
        Ok(())
    });
}

trait CloneForData {
    fn clone_for_data(&self) -> Rng;
}

impl CloneForData for Rng {
    /// Derive a data-stream RNG so the two program variants see
    /// identical inputs regardless of how many draws each makes.
    fn clone_for_data(&self) -> Rng {
        self.clone()
    }
}
