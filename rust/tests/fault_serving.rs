//! Fault-injection and resilient-serving integration tests
//! (DESIGN.md §12).
//!
//! Two layers are covered:
//!
//! - **Simulator**: scripted [`FaultPlan`]s must change exactly what
//!   they claim (slowdowns scale compute, stalls add cycles, transient
//!   failures corrupt SPM and flag the cluster, offline clusters
//!   execute nothing) — and a *zero-impact* plan must leave both
//!   simulator paths bit-identical to running with no plan at all.
//! - **Serving**: the resilient loop must retry around failed
//!   clusters without double-counting tokens, quarantine them, shed
//!   over admission limits, honor deadlines, walk the degradation
//!   ladder under pressure, and reproduce a whole chaos run from its
//!   seed.

use vexp::exec::program::Program;
use vexp::exec::{
    AnalyticBackend, CycleSimBackend, Engine, Outcome, Request, ServeOptions, ServeReport,
    TraceSpec,
};
use vexp::kernels::flash_attention::{
    build_fa_decode_program, build_fa_program, seed_fa_decode_inputs, seed_fa_inputs, FaVariant,
};
use vexp::kernels::gelu::{build_gelu_program, seed_gelu_inputs, GeluForm, GeluVariant};
use vexp::kernels::layernorm::{build_layernorm_program, seed_layernorm_inputs, LayerNormVariant};
use vexp::kernels::softmax::{
    build_softmax_bwd_program, build_softmax_program, seed_softmax_bwd_inputs,
    seed_softmax_inputs, SoftmaxBwdVariant, SoftmaxVariant,
};
use vexp::model::{GPT2_SMALL, VIT_BASE};
use vexp::sim::{
    spm_checksum, ClusterFault, ClusterJob, DmaModel, FaultEvent, FaultPlan, FaultSpec, Mem,
    System, SystemStats, SPM_BYTES,
};

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

type Seeder = Box<dyn Fn(&mut Mem)>;

/// The kernel matrix for the zero-impact differential: softmax (both
/// the optimized and baseline variants), FA-2 prefill, FA-2 decode, and
/// the nonlinearity kernels (GELU, LayerNorm, softmax backward).
fn kernel_suite() -> Vec<(&'static str, Program, Seeder)> {
    vec![
        (
            "gelu/Hw(Tanh)",
            build_gelu_program(GeluVariant::Hw(GeluForm::Tanh), 4, 64),
            Box::new(|spm: &mut Mem| seed_gelu_inputs(spm, 4, 64, 11)),
        ),
        (
            "layernorm/Optimized",
            build_layernorm_program(LayerNormVariant::Optimized, 8, 64),
            Box::new(|spm: &mut Mem| seed_layernorm_inputs(spm, 8, 64, 12)),
        ),
        (
            "softmax-bwd/Optimized",
            build_softmax_bwd_program(SoftmaxBwdVariant::Optimized, 8, 64),
            Box::new(|spm: &mut Mem| seed_softmax_bwd_inputs(spm, 8, 64, 13)),
        ),
        (
            "softmax/SwExpHw",
            build_softmax_program(SoftmaxVariant::SwExpHw, 8, 64),
            Box::new(|spm: &mut Mem| seed_softmax_inputs(spm, 8, 64, 42)),
        ),
        (
            "softmax/Baseline",
            build_softmax_program(SoftmaxVariant::Baseline, 4, 64),
            Box::new(|spm: &mut Mem| seed_softmax_inputs(spm, 4, 64, 42)),
        ),
        (
            "fa2/Optimized",
            build_fa_program(FaVariant::Optimized, 16, 64, 64, 32),
            Box::new(|spm: &mut Mem| seed_fa_inputs(spm, 16, 64, 64, 32, 7)),
        ),
        (
            "fa2-decode/Optimized",
            build_fa_decode_program(FaVariant::Optimized, 64, 64, 16),
            Box::new(|spm: &mut Mem| seed_fa_decode_inputs(spm, 64, 64, 16, 7)),
        ),
    ]
}

/// Run `program` on both clusters of a 2-cluster system for two fault
/// epochs and return (per-epoch stats, final per-cluster SPM sums).
fn run_twice(
    program: &Program,
    seeder: &dyn Fn(&mut Mem),
    plan: Option<FaultPlan>,
    reference: bool,
) -> (Vec<SystemStats>, Vec<u64>) {
    let mut sys = System::new(2);
    sys.reference_interp = reference;
    sys.faults = plan;
    let mut epochs = Vec::new();
    for _ in 0..2 {
        for cl in &mut sys.clusters {
            seeder(&mut cl.spm);
        }
        epochs.push(sys.run_jobs(vec![
            ClusterJob::new(vec![program.clone()], 4096),
            ClusterJob::new(vec![program.clone()], 4096),
        ]));
    }
    let sums = sys.clusters.iter().map(|c| spm_checksum(&c.spm)).collect();
    (epochs, sums)
}

fn assert_stats_identical(a: &SystemStats, b: &SystemStats, ctx: &str) {
    assert_eq!(a.cycles, b.cycles, "{ctx}: makespan");
    assert_eq!(a.hbm_bytes, b.hbm_bytes, "{ctx}: hbm bytes");
    assert_eq!(a.error_bound_cycles, b.error_bound_cycles, "{ctx}: error bound");
    assert_eq!(a.faults_injected, b.faults_injected, "{ctx}: faults injected");
    assert_eq!(a.injected_cycles, b.injected_cycles, "{ctx}: injected cycles");
    assert_eq!(a.failed_clusters, b.failed_clusters, "{ctx}: failed clusters");
    assert_eq!(a.offline_clusters, b.offline_clusters, "{ctx}: offline clusters");
    assert_eq!(a.per_cluster.len(), b.per_cluster.len(), "{ctx}: cluster count");
    for (i, (x, y)) in a.per_cluster.iter().zip(&b.per_cluster).enumerate() {
        assert_eq!(x.cycles, y.cycles, "{ctx}: cluster {i} cycles");
        assert_eq!(x.dma_bytes, y.dma_bytes, "{ctx}: cluster {i} dma bytes");
        assert_eq!(x.dma_cycles, y.dma_cycles, "{ctx}: cluster {i} dma cycles");
        assert_eq!(x.failed, y.failed, "{ctx}: cluster {i} failed");
        assert_eq!(x.offline, y.offline, "{ctx}: cluster {i} offline");
        assert_eq!(x.injected_cycles, y.injected_cycles, "{ctx}: cluster {i} injected");
        assert_eq!(x.faults_injected, y.faults_injected, "{ctx}: cluster {i} faults");
    }
}

fn zero_impact_differential(reference: bool) {
    for (name, program, seeder) in kernel_suite() {
        let (clean, clean_sums) = run_twice(&program, &seeder, None, reference);
        let plan = FaultPlan::new(FaultSpec::zero_impact(), 7, 2);
        let (zero, zero_sums) = run_twice(&program, &seeder, Some(plan), reference);
        for (epoch, (a, b)) in clean.iter().zip(&zero).enumerate() {
            assert_stats_identical(a, b, &format!("{name} epoch {epoch}"));
            assert_eq!(b.faults_injected, 0, "{name}: zero-impact plan must inject nothing");
        }
        assert_eq!(clean_sums, zero_sums, "{name}: SPM bytes must be bit-identical");
    }
}

fn softmax_prog() -> Program {
    build_softmax_program(SoftmaxVariant::SwExpHw, 8, 64)
}

fn seed_sm(spm: &mut Mem, seed: u64) {
    seed_softmax_inputs(spm, 8, 64, seed);
}

/// A decode request on a seq-shortened GPT-2 Small.
fn gpt(seq: u32, tokens: u32) -> Request {
    let mut cfg = GPT2_SMALL;
    cfg.seq = seq;
    Request::new(0, cfg).with_tokens(tokens)
}

// ---------------------------------------------------------------------------
// simulator layer
// ---------------------------------------------------------------------------

#[test]
fn zero_impact_faults_are_bit_identical_fast_path() {
    zero_impact_differential(false);
}

#[test]
fn zero_impact_faults_are_bit_identical_reference_interp() {
    zero_impact_differential(true);
}

#[test]
fn scripted_slowdown_scales_compute_exactly() {
    let p = softmax_prog();
    let mut clean_sys = System::new(1);
    seed_sm(&mut clean_sys.clusters[0].spm, 1);
    let clean = clean_sys.run_jobs(vec![ClusterJob::new(vec![p.clone()], 0)]);

    let fill = u64::from(DmaModel::default().startup);
    let compute = clean.cycles - fill;
    let mut sys = System::new(1);
    sys.faults = Some(FaultPlan::scripted(
        1,
        vec![FaultEvent {
            cluster: 0,
            from_epoch: 0,
            until_epoch: 1,
            fault: ClusterFault { slow_factor: 2.0, ..ClusterFault::none() },
        }],
    ));
    seed_sm(&mut sys.clusters[0].spm, 1);
    let s = sys.run_jobs(vec![ClusterJob::new(vec![p.clone()], 0)]);
    assert_eq!(s.cycles, 2 * compute + fill, "2x slowdown doubles compute, not fill");
    assert_eq!(s.injected_cycles, compute);
    assert_eq!(s.faults_injected, 1);
    assert!(s.failed_clusters.is_empty());

    // the event window [0, 1) has closed: the next epoch runs clean
    seed_sm(&mut sys.clusters[0].spm, 1);
    let s2 = sys.run_jobs(vec![ClusterJob::new(vec![p], 0)]);
    assert_eq!(s2.cycles, clean.cycles);
    assert_eq!(s2.faults_injected, 0);
}

#[test]
fn scripted_stall_adds_exactly_its_cycles() {
    let p = softmax_prog();
    let mut clean_sys = System::new(1);
    seed_sm(&mut clean_sys.clusters[0].spm, 2);
    let clean = clean_sys.run_jobs(vec![ClusterJob::new(vec![p.clone()], 0)]);

    let mut sys = System::new(1);
    sys.faults = Some(FaultPlan::scripted(
        1,
        vec![FaultEvent {
            cluster: 0,
            from_epoch: 0,
            until_epoch: 1,
            fault: ClusterFault { stall_cycles: 7_000, ..ClusterFault::none() },
        }],
    ));
    seed_sm(&mut sys.clusters[0].spm, 2);
    let s = sys.run_jobs(vec![ClusterJob::new(vec![p], 0)]);
    assert_eq!(s.cycles, clean.cycles + 7_000);
    assert_eq!(s.injected_cycles, 7_000);
    assert_eq!(s.faults_injected, 1);
}

#[test]
fn scripted_transient_failure_corrupts_spm_and_clears_next_epoch() {
    let p = softmax_prog();
    let zeros = vec![0u8; SPM_BYTES];

    // clean reference image
    let mut clean_sys = System::new(1);
    clean_sys.clusters[0].spm.load_bytes(0, &zeros);
    seed_sm(&mut clean_sys.clusters[0].spm, 3);
    clean_sys.run_jobs(vec![ClusterJob::new(vec![p.clone()], 0)]);
    let clean_sum = spm_checksum(&clean_sys.clusters[0].spm);

    let mut sys = System::new(1);
    sys.faults = Some(FaultPlan::scripted(
        1,
        vec![FaultEvent {
            cluster: 0,
            from_epoch: 0,
            until_epoch: 1,
            fault: ClusterFault { fail: true, ..ClusterFault::none() },
        }],
    ));
    sys.clusters[0].spm.load_bytes(0, &zeros);
    seed_sm(&mut sys.clusters[0].spm, 3);
    let s1 = sys.run_jobs(vec![ClusterJob::new(vec![p.clone()], 0)]);
    assert_eq!(s1.failed_clusters, vec![0]);
    assert!(s1.per_cluster[0].failed);
    assert_eq!(s1.faults_injected, 1);
    assert_ne!(
        spm_checksum(&sys.clusters[0].spm),
        clean_sum,
        "the corruption must be visible in the SPM checksum"
    );

    // retry epoch: reset + reseed; the fault window has passed
    sys.clusters[0].spm.load_bytes(0, &zeros);
    seed_sm(&mut sys.clusters[0].spm, 3);
    let s2 = sys.run_jobs(vec![ClusterJob::new(vec![p], 0)]);
    assert!(s2.failed_clusters.is_empty());
    assert!(!s2.per_cluster[0].failed);
    assert_eq!(spm_checksum(&sys.clusters[0].spm), clean_sum, "retry must run clean");
}

#[test]
fn scripted_offline_cluster_executes_nothing_and_drops_its_job() {
    let p = softmax_prog();
    let mut sys = System::new(2);
    sys.faults = Some(FaultPlan::scripted(
        2,
        vec![FaultEvent {
            cluster: 1,
            from_epoch: 0,
            until_epoch: u64::MAX,
            fault: ClusterFault { offline: true, ..ClusterFault::none() },
        }],
    ));
    seed_sm(&mut sys.clusters[0].spm, 4);
    seed_sm(&mut sys.clusters[1].spm, 4);
    let before = spm_checksum(&sys.clusters[1].spm);
    let s = sys.run_jobs(vec![
        ClusterJob::new(vec![p.clone()], 0),
        ClusterJob::new(vec![p], 0),
    ]);
    assert_eq!(s.offline_clusters, vec![1]);
    assert_eq!(s.failed_clusters, vec![1], "an offline cluster's pending job is lost");
    assert!(s.per_cluster[1].offline);
    assert_eq!(s.per_cluster[1].cycles, 0);
    assert_eq!(spm_checksum(&sys.clusters[1].spm), before, "offline SPM is untouched");
    assert_eq!(s.cycles, s.per_cluster[0].cycles, "makespan excludes the offline cluster");
}

// ---------------------------------------------------------------------------
// serving layer
// ---------------------------------------------------------------------------

#[test]
fn transient_failure_triggers_retry_and_quarantine_without_double_count() {
    let mut engine = Engine::with_clusters(4);
    engine.submit_request(gpt(64, 2));
    let mut backend = CycleSimBackend::new(4);
    backend.system.faults = Some(FaultPlan::scripted(
        4,
        vec![FaultEvent {
            cluster: 0,
            from_epoch: 0,
            until_epoch: 1,
            fault: ClusterFault { fail: true, ..ClusterFault::none() },
        }],
    ));
    let opts = ServeOptions { max_attempts: 3, quarantine_iters: 1, ..Default::default() };
    let report = engine.serve(&mut backend, None, &opts);
    report.assert_consistent();

    assert_eq!(report.per_request.len(), 1);
    let r = &report.per_request[0];
    assert_eq!(r.outcome, Outcome::Completed);
    assert_eq!(r.tokens, 2, "a retried iteration must not double-count tokens");
    assert_eq!(r.retries, 1);
    assert_eq!(report.total_tokens(), 2);
    assert_eq!(report.slo.retries, 1);
    assert!(report.slo.faults_injected >= 1);
    assert_eq!(report.slo.quarantine_events, 1);
    assert_eq!(report.log[0].attempts, 2, "iteration 0 = failed attempt + clean retry");
    assert_eq!(report.health[0].failures, 1);
    assert!(report.health[0].quarantined_iters >= 1);
}

#[test]
fn admission_control_sheds_over_queue_depth() {
    let mut engine = Engine::with_clusters(4);
    for _ in 0..6 {
        engine.submit_request(gpt(32, 1));
    }
    let mut backend = AnalyticBackend::new();
    let opts = ServeOptions { max_live: 1, max_queue: 0, ..Default::default() };
    let report = engine.serve(&mut backend, None, &opts);
    report.assert_consistent();

    assert_eq!(report.slo.shed, 5, "1 admitted, 0 allowed to wait, 5 shed");
    assert_eq!(report.slo.completed, 1);
    let shed: Vec<_> = report
        .per_request
        .iter()
        .filter(|r| r.outcome == Outcome::Shed)
        .collect();
    assert_eq!(shed.len(), 5);
    assert!(shed.iter().all(|r| r.tokens == 0), "shed requests generate no tokens");
    let served = report.total_tokens();
    assert_eq!(served, 1, "throughput counts only served requests");
}

#[test]
fn deadline_expiry_times_out_with_partial_progress() {
    let mut engine = Engine::with_clusters(4);
    engine.submit_request(gpt(32, 50));
    let mut backend = AnalyticBackend::new();
    let opts = ServeOptions { deadline_cycles: Some(1), ..Default::default() };
    let report = engine.serve(&mut backend, None, &opts);
    report.assert_consistent();

    let r = &report.per_request[0];
    assert_eq!(r.outcome, Outcome::TimedOut);
    assert!(r.tokens < 50, "the deadline must cut the request short");
    assert_eq!(report.slo.timed_out, 1);
    assert_eq!(report.slo.completed, 0);
}

#[test]
fn overload_walks_the_degradation_ladder_and_recovers() {
    let mut engine = Engine::with_clusters(4);
    engine.submit_request(gpt(32, 1));
    engine.submit_request(gpt(32, 3));
    engine.submit_request(gpt(32, 5));
    let mut primary = CycleSimBackend::new(4);
    let mut fallback = AnalyticBackend::new();
    let opts = ServeOptions {
        degrade_sampled_at: 2,
        degrade_analytic_at: 3,
        ..Default::default()
    };
    let report = engine.serve(&mut primary, Some(&mut fallback), &opts);
    report.assert_consistent();

    let s = &report.slo;
    assert!(s.analytic_iters >= 1, "pressure 3 must reach the analytic tier");
    assert!(s.sampled_iters >= 1, "pressure 2 must reach the sampled tier");
    assert!(s.full_iters >= 1, "the loop must recover full fidelity as pressure drops");
    assert_eq!(s.full_iters + s.sampled_iters + s.analytic_iters, report.iterations);
    assert!(report.per_request.iter().all(|r| r.outcome == Outcome::Completed));
    assert_eq!(report.total_tokens(), 1 + 3 + 5, "degraded iterations still make progress");
}

#[test]
fn sampled_degradation_works_without_a_fallback_backend() {
    let mut engine = Engine::with_clusters(4);
    engine.submit_request(gpt(32, 2));
    engine.submit_request(gpt(32, 2));
    let mut primary = CycleSimBackend::new(4);
    let opts = ServeOptions { degrade_sampled_at: 2, ..Default::default() };
    let report = engine.serve(&mut primary, None, &opts);
    report.assert_consistent();
    assert!(report.slo.sampled_iters >= 1);
    assert_eq!(
        report.slo.full_iters + report.slo.sampled_iters + report.slo.analytic_iters,
        report.iterations
    );
    assert!(report.per_request.iter().all(|r| r.outcome == Outcome::Completed));
}

fn serve_mixed(plan: Option<FaultPlan>) -> (ServeReport, Vec<u64>) {
    let mut engine = Engine::with_clusters(4);
    engine.submit_request(gpt(64, 2));
    let mut vit = VIT_BASE;
    vit.seq = 64;
    engine.submit_request(Request::new(0, vit));
    let mut backend = CycleSimBackend::new(4);
    backend.system.faults = plan;
    let report = engine.serve(&mut backend, None, &ServeOptions::legacy(32));
    report.assert_consistent();
    let sums = backend
        .system
        .clusters
        .iter()
        .map(|c| spm_checksum(&c.spm))
        .collect();
    (report, sums)
}

#[test]
fn zero_impact_faults_leave_a_serve_run_bit_identical() {
    let (clean, clean_sums) = serve_mixed(None);
    let plan = FaultPlan::new(FaultSpec::zero_impact(), 5, 4);
    let (zero, zero_sums) = serve_mixed(Some(plan));

    assert_eq!(clean.iterations, zero.iterations);
    assert_eq!(clean.total_cycles, zero.total_cycles);
    assert_eq!(zero.slo.faults_injected, 0);
    assert_eq!(clean.per_request.len(), zero.per_request.len());
    for (a, b) in clean.per_request.iter().zip(&zero.per_request) {
        assert_eq!(a.request_id, b.request_id);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.cycles.to_bits(), b.cycles.to_bits());
        assert_eq!(a.ttft_cycles.to_bits(), b.ttft_cycles.to_bits());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        assert_eq!(a.decode_token_cycles.to_bits(), b.decode_token_cycles.to_bits());
    }
    assert_eq!(clean_sums, zero_sums, "SPM images must match byte-for-byte");
}

fn chaos_trace_run(seed: u64) -> ServeReport {
    let spec = TraceSpec::bursty(6, 50_000.0, seed);
    let mut engine = Engine::with_clusters(4);
    for r in spec.mixed_traffic(32, 2, Some(10_000_000)) {
        engine.submit_request(r);
    }
    let mut primary = CycleSimBackend::new(4);
    primary.system.faults = Some(FaultPlan::new(FaultSpec::chaos(), seed, 4));
    let mut fallback = AnalyticBackend::new();
    let opts = ServeOptions::new()
        .max_iters(64)
        .max_live(2)
        .max_queue(2)
        .ttft_slo(5_000_000)
        .token_slo(1_000_000)
        .shed_over_projected_ttft(true)
        .max_attempts(3)
        .quarantine_iters(2)
        .degrade_at(3, 5);
    let report = engine.serve(&mut primary, Some(&mut fallback), &opts);
    report.assert_consistent();
    report
}

#[test]
fn chaos_trace_serving_is_reproducible_from_its_seed() {
    let a = chaos_trace_run(7);
    let b = chaos_trace_run(7);

    assert_eq!(a.iterations, b.iterations);
    assert_eq!(a.total_cycles, b.total_cycles);
    assert_eq!(a.per_request.len(), b.per_request.len());
    for (x, y) in a.per_request.iter().zip(&b.per_request) {
        assert_eq!(x.request_id, y.request_id);
        assert_eq!(x.outcome, y.outcome);
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.retries, y.retries);
        assert_eq!(x.cycles.to_bits(), y.cycles.to_bits());
        assert_eq!(x.ttft_cycles.to_bits(), y.ttft_cycles.to_bits());
    }
    let (sa, sb) = (&a.slo, &b.slo);
    assert_eq!(
        (sa.completed, sa.shed, sa.timed_out, sa.unfinished),
        (sb.completed, sb.shed, sb.timed_out, sb.unfinished)
    );
    assert_eq!(
        (sa.retries, sa.faults_injected, sa.quarantine_events),
        (sb.retries, sb.faults_injected, sb.quarantine_events)
    );
    assert_eq!(
        (sa.full_iters, sa.sampled_iters, sa.analytic_iters),
        (sb.full_iters, sb.sampled_iters, sb.analytic_iters)
    );
    for (h1, h2) in a.health.iter().zip(&b.health) {
        assert_eq!(
            (h1.cluster, h1.failures, h1.quarantined_iters, h1.offline),
            (h2.cluster, h2.failures, h2.quarantined_iters, h2.offline)
        );
    }
    // a different seed must produce a genuinely different run
    let c = chaos_trace_run(8);
    assert!(
        c.total_cycles != a.total_cycles || c.slo.faults_injected != a.slo.faults_injected,
        "seed must steer both the trace and the fault plan"
    );
}
