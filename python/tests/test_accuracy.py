"""Table-II artifacts: accuracy table and golden-table consistency."""

import json
import os

import numpy as np
import pytest

from compile.kernels.vexp import vexp_numpy_bits

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_golden_table_matches_current_spec():
    """The dumped golden table must match the in-tree kernel — catches
    spec drift between `make artifacts` and later kernel edits."""
    path = os.path.join(ART, "vexp_golden.bin")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    golden = np.fromfile(path, dtype="<u2")
    assert golden.shape == (65536,)
    now = vexp_numpy_bits(np.arange(65536, dtype=np.uint32).astype(np.uint16))
    assert np.array_equal(golden, now), "golden table stale — re-run make artifacts"


def test_accuracy_table_shape():
    """After `make accuracy`: BF16+VEXP within 0.1% of BF16 (Table II)."""
    path = os.path.join(ART, "accuracy_table.json")
    if not os.path.exists(path):
        pytest.skip("run `make accuracy` first")
    with open(path) as f:
        table = json.load(f)
    r = table["results"]
    fp32 = r["FP32"]["perplexity"]
    bf16 = r["BF16"]["perplexity"]
    vexp = r["BF16 EXP"]["perplexity"]
    # trained model: far below the uniform-vocabulary baseline of 64
    assert fp32 < 32.0
    # BF16 cast is benign
    assert abs(bf16 - fp32) / fp32 < 0.02
    # the paper's headline: VEXP adds <0.1% on top of BF16
    assert abs(vexp - bf16) / bf16 < 0.001


def test_manifest_covers_all_hlo_files():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as f:
        manifest = json.load(f)
    for name, ep in manifest["entry_points"].items():
        hlo = os.path.join(ART, ep["file"])
        assert os.path.exists(hlo), f"{name}: {ep['file']} missing"
        with open(hlo) as g:
            head = g.read(4096)
        assert "HloModule" in head, f"{name}: not HLO text"
