"""Build-time training utilities (Table II substitution machinery)."""

import numpy as np

from compile.train import (D0, EQ, PLUS, SEP, TIMES, VOCAB, adam_init,
                           adam_step, batches, make_corpus)


def test_corpus_tokens_in_vocab():
    c = make_corpus(10_000, seed=0)
    assert c.dtype == np.int32
    assert c.min() >= 0 and c.max() < VOCAB
    assert len(c) == 10_000


def test_corpus_is_structured():
    """Arithmetic sentences: after "ab+cd=" the next two tokens encode
    (ab+cd) mod 100 — verify on parsed occurrences."""
    c = make_corpus(50_000, seed=1)
    checked = 0
    i = 0
    while i < len(c) - 9:
        if (c[i] < 10 and c[i + 1] < 10 and c[i + 2] == PLUS
                and c[i + 3] < 10 and c[i + 4] < 10 and c[i + 5] == EQ
                and c[i + 6] < 10 and c[i + 7] < 10 and c[i + 8] == SEP):
            a = 10 * c[i] + c[i + 1]
            b = 10 * c[i + 3] + c[i + 4]
            r = 10 * c[i + 6] + c[i + 7]
            assert r == (a + b) % 100
            checked += 1
            i += 9
        else:
            i += 1
    assert checked > 100


def test_corpus_deterministic():
    assert np.array_equal(make_corpus(1000, seed=5), make_corpus(1000, seed=5))
    assert not np.array_equal(make_corpus(1000, seed=5),
                              make_corpus(1000, seed=6))


def test_batches_shape():
    c = make_corpus(20_000, seed=0)
    bs = list(batches(c, batch=4, steps=3, seed=0))
    assert len(bs) == 3
    for b in bs:
        assert b.shape == (4, 129)  # SEQ + 1 for next-token targets


def test_adam_moves_params():
    import jax.numpy as jnp
    p = {"w": jnp.ones((4, 4))}
    g = {"w": jnp.ones((4, 4)) * 0.5}
    st = adam_init(p)
    p2, st2 = adam_step(p, g, st, lr=1e-2)
    assert st2["t"] == 1
    assert float(jnp.abs(p2["w"] - p["w"]).max()) > 1e-4
    # adam step size is bounded by lr at t=1
    assert float(jnp.abs(p2["w"] - p["w"]).max()) < 2e-2
