"""Layer-2 model: shapes, flat-parameter packing, softmax-mode ablation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelConfig, flatten_params, forward, forward_flat, init_params,
    loss_fn, num_params, param_spec, unflatten_params,
)

CFG = ModelConfig(vocab=32, d_model=64, n_heads=2, n_layers=2, d_ff=128,
                  max_seq=32)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    return jnp.asarray(np.random.RandomState(0).randint(0, CFG.vocab, (2, 16)),
                       jnp.int32)


def test_forward_shape(params, tokens):
    logits = forward(params, tokens, CFG)
    assert logits.shape == (2, 16, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("mode", ["fp32", "bf16", "bf16_exp"])
def test_modes_agree(params, tokens, mode):
    """The three Table-II numeric configurations must be close on logits."""
    base = forward(params, tokens, CFG, "fp32")
    got = forward(params, tokens, CFG, mode)
    assert float(jnp.abs(got - base).max()) < 0.1


def test_causality(params):
    """Changing a future token must not change past logits."""
    t1 = jnp.asarray(np.random.RandomState(1).randint(0, CFG.vocab, (1, 16)),
                     jnp.int32)
    t2 = t1.at[0, 10].set((int(t1[0, 10]) + 1) % CFG.vocab)
    a = forward(params, t1, CFG)
    b = forward(params, t2, CFG)
    assert float(jnp.abs(a[0, :10] - b[0, :10]).max()) < 1e-5


def test_loss_finite_and_reasonable(params, tokens):
    loss = float(loss_fn(params, tokens, CFG))
    # random init: loss ~ log(vocab) = 3.47
    assert 2.0 < loss < 6.0


def test_loss_decreases_under_sgd(params):
    toks = jnp.asarray(np.random.RandomState(2).randint(0, CFG.vocab, (4, 17)),
                       jnp.int32)
    g = jax.jit(jax.value_and_grad(lambda p: loss_fn(p, toks, CFG)))
    p = params
    l0, grads = g(p)
    for _ in range(8):
        p = jax.tree.map(lambda w, d: w - 0.05 * d, p, grads)
        l1, grads = g(p)
    assert float(l1) < float(l0)


def test_param_spec_counts():
    n = num_params(CFG)
    assert n == sum(int(np.prod(s)) for _, s in param_spec(CFG))
    # d_model**2 terms dominate; sanity-check the order of magnitude
    assert 50_000 < n < 500_000


def test_flatten_roundtrip(params, tokens):
    theta = flatten_params(params, CFG)
    assert theta.shape == (num_params(CFG),)
    re = unflatten_params(jnp.asarray(theta), CFG)
    a = forward(params, tokens, CFG)
    b = forward(re, tokens, CFG)
    assert float(jnp.abs(a - b).max()) < 1e-5


def test_forward_flat_matches_forward(params, tokens):
    theta = jnp.asarray(flatten_params(params, CFG))
    a = forward(params, tokens, CFG, "bf16_exp")
    b = forward_flat(tokens, theta, CFG, "bf16_exp")
    assert float(jnp.abs(a - b).max()) < 1e-5
