"""VEXP kernel correctness: the CORE Layer-1 signal.

Checks, in order of strength:
  1. exhaustive bit-equality between the jnp and numpy twins (2^16 inputs);
  2. error bounds vs the exact exponential (paper §V-A: mean 0.14 %,
     max 0.78 %; our locked spec measures 0.030 % / 0.95 %);
  3. IEEE-special handling (NaN/±inf/zero/subnormal FTZ);
  4. the Pallas kernel is bit-identical to the jnp path over shapes/dtypes
     (hypothesis sweep).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import exp_ref
from compile.kernels.vexp import (
    bf16_to_bits, bits_to_bf16, vexp, vexp_bits, vexp_numpy_bits, vexp_pallas,
)

ALL_BITS = np.arange(65536, dtype=np.uint32)


@pytest.fixture(scope="module")
def golden():
    return vexp_numpy_bits(ALL_BITS.astype(np.uint16))


def test_jnp_matches_numpy_exhaustive(golden):
    out = np.asarray(vexp_bits(jnp.asarray(ALL_BITS, jnp.uint32)))
    assert np.array_equal(out.astype(np.uint16), golden)


def test_error_bounds_exhaustive(golden):
    """Mean/max relative error vs f64 exp over all finite, in-range inputs."""
    x = (ALL_BITS.astype(np.uint32) << 16).view(np.float32).astype(np.float64)
    y = (golden.astype(np.uint32) << 16).view(np.float32).astype(np.float64)
    with np.errstate(over="ignore"):
        t = np.exp(x)
    ok = np.isfinite(x) & np.isfinite(t) & (t > 1e-38) & (t < 3.38e38)
    rel = np.abs(y[ok] - t[ok]) / t[ok]
    assert rel.mean() < 0.002, f"mean rel err {rel.mean():.5f}"
    assert rel.max() < 0.011, f"max rel err {rel.max():.5f}"


def test_monotone_on_grid(golden):
    """exp is monotone; the approximation must be non-decreasing on
    positive-representable inputs (sorted by value)."""
    x = (ALL_BITS.astype(np.uint32) << 16).view(np.float32)
    finite = np.isfinite(x) & (np.abs(x) < 80)
    order = np.argsort(x[finite], kind="stable")
    y = (golden[finite].astype(np.uint32) << 16).view(np.float32)[order]
    assert np.all(np.diff(y) >= 0)


@pytest.mark.parametrize("bits,expect", [
    (0x0000, 0x3F80),   # +0      -> 1.0
    (0x8000, 0x3F80),   # -0      -> 1.0
    (0x0001, 0x3F80),   # +subnormal (FTZ) -> 1.0
    (0x8001, 0x3F80),   # -subnormal (FTZ) -> 1.0
    (0x7F80, 0x7F80),   # +inf    -> +inf
    (0xFF80, 0x0000),   # -inf    -> 0
])
def test_specials(bits, expect):
    out = int(np.asarray(vexp_bits(jnp.asarray([bits], jnp.uint32)))[0])
    assert out == expect, f"exp({bits:#06x}) = {out:#06x}, want {expect:#06x}"


def test_nan_propagates():
    out = int(np.asarray(vexp_bits(jnp.asarray([0x7FC1], jnp.uint32)))[0])
    e, m = (out >> 7) & 0xFF, out & 0x7F
    assert e == 0xFF and m != 0


def test_overflow_to_inf():
    # exp(128) overflows bf16: 128 = 0x4300
    out = int(np.asarray(vexp_bits(jnp.asarray([0x4300], jnp.uint32)))[0])
    assert out == 0x7F80


def test_underflow_to_zero():
    # exp(-128) = 3.8e-56, below bf16 normal range
    out = int(np.asarray(vexp_bits(jnp.asarray([0xC300], jnp.uint32)))[0])
    assert out == 0x0000


def test_exp_zero_is_one():
    assert float(vexp(jnp.asarray([0.0], jnp.bfloat16))[0]) == 1.0


def test_exp_one_close_to_e():
    y = float(vexp(jnp.asarray([1.0], jnp.bfloat16))[0])
    assert abs(y - np.e) / np.e < 0.01


def test_bitcast_roundtrip():
    x = jnp.asarray([1.5, -2.25, 0.0, 100.0], jnp.bfloat16)
    assert jnp.all(bits_to_bf16(bf16_to_bits(x)) == x)


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 64),
    cols=st.integers(1, 256),
    scale=st.floats(0.1, 40.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_matches_jnp(rows, cols, scale, seed):
    """Hypothesis sweep: the Pallas kernel is bit-identical to plain jnp."""
    rng = np.random.RandomState(seed % 100000)
    x = jnp.asarray(rng.uniform(-scale, scale / 4, (rows, cols)), jnp.bfloat16)
    a = vexp_pallas(x)
    b = vexp(x)
    assert np.array_equal(np.asarray(bf16_to_bits(a)), np.asarray(bf16_to_bits(b)))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 1024), seed=st.integers(0, 1000))
def test_pallas_1d(n, seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.normal(0, 3, (n,)), jnp.bfloat16)
    assert np.array_equal(
        np.asarray(vexp_pallas(x).astype(jnp.float32)),
        np.asarray(vexp(x).astype(jnp.float32)),
    )


@settings(max_examples=10, deadline=None)
@given(dtype=st.sampled_from(["float32", "float64", "bfloat16", "float16"]))
def test_dtype_coercion(dtype):
    """Any float dtype in; bf16 semantics always apply."""
    x = jnp.asarray([0.5, -1.0, 3.0], dtype)
    y = np.asarray(vexp_pallas(x).astype(jnp.float32))
    t = np.exp(np.asarray(x.astype(jnp.float32)))
    assert np.all(np.abs(y - t) / t < 0.02)
