"""FlashAttention-2 kernel vs exact attention (paper §III-B / §IV-D)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.flash_attention import (
    flash_attention_pallas, flash_attention_rows, mha_flash,
)
from compile.kernels.ref import attention_ref


def qkv(sq, sk, d, seed=0):
    rng = np.random.RandomState(seed)
    return (jnp.asarray(rng.normal(size=(sq, d)), jnp.float32),
            jnp.asarray(rng.normal(size=(sk, d)), jnp.float32),
            jnp.asarray(rng.normal(size=(sk, d)), jnp.float32))


@pytest.mark.parametrize("sq,sk,d", [(16, 16, 16), (64, 128, 64),
                                     (128, 256, 64), (32, 96, 32)])
@pytest.mark.parametrize("use_vexp", [True, False])
def test_close_to_exact_attention(sq, sk, d, use_vexp):
    q, k, v = qkv(sq, sk, d, seed=sq + sk)
    got = np.asarray(flash_attention_pallas(q, k, v, use_vexp=use_vexp)
                     .astype(jnp.float32))
    want = np.asarray(attention_ref(q, k, v))
    assert np.abs(got - want).max() < 0.02


def test_block_size_invariance():
    """K-block tiling (the SPM double-buffer granularity) must be
    numerically invisible for the exact-exp variant in f32 statistics."""
    q, k, v = qkv(64, 256, 64, seed=1)
    a = np.asarray(flash_attention_pallas(q, k, v, block_k=32,
                                          use_vexp=False).astype(jnp.float32))
    b = np.asarray(flash_attention_pallas(q, k, v, block_k=256,
                                          use_vexp=False).astype(jnp.float32))
    assert np.abs(a - b).max() < 2e-2


def test_rows_matches_pallas():
    q, k, v = qkv(32, 64, 32, seed=2)
    a = np.asarray(flash_attention_rows(q.astype(jnp.bfloat16),
                                        k.astype(jnp.bfloat16),
                                        v.astype(jnp.bfloat16)))
    b = np.asarray(flash_attention_pallas(q, k, v).astype(jnp.float32))
    assert np.abs(a - b).max() < 2e-2


def test_one_hot_value_passthrough():
    """If one key dominates, the output must be ~that key's value row."""
    d = 32
    q = jnp.ones((4, d), jnp.float32) * 3.0
    k = jnp.asarray(np.vstack([np.ones((1, d)) * 3.0,
                               -np.ones((7, d)) * 3.0]), jnp.float32)
    v = jnp.asarray(np.random.RandomState(3).normal(size=(8, d)), jnp.float32)
    got = np.asarray(flash_attention_pallas(q, k, v).astype(jnp.float32))
    assert np.abs(got - np.asarray(v)[0]).max() < 0.05


def test_mha_vmap_heads():
    h, s, d = 4, 64, 32
    rng = np.random.RandomState(4)
    q = jnp.asarray(rng.normal(size=(h, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(h, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(h, s, d)), jnp.float32)
    got = np.asarray(mha_flash(q, k, v).astype(jnp.float32))
    for i in range(h):
        want = np.asarray(attention_ref(q[i], k[i], v[i]))
        assert np.abs(got[i] - want).max() < 0.02


@settings(max_examples=12, deadline=None)
@given(sq=st.integers(4, 64), sk=st.integers(4, 128),
       d=st.sampled_from([8, 16, 32, 64]), seed=st.integers(0, 1000),
       use_vexp=st.booleans())
def test_hypothesis_sweep(sq, sk, d, seed, use_vexp):
    q, k, v = qkv(sq, sk, d, seed=seed)
    got = np.asarray(flash_attention_pallas(q, k, v, use_vexp=use_vexp)
                     .astype(jnp.float32))
    assert got.shape == (sq, d)
    assert np.isfinite(got).all()
    want = np.asarray(attention_ref(q, k, v))
    assert np.abs(got - want).max() < 0.03
