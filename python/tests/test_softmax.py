"""Fused softmax kernel vs the exact oracle (paper §IV-C structure)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import softmax_ref
from compile.kernels.softmax import softmax_pallas, softmax_rows


def rand(shape, seed=0, lo=-10.0, hi=10.0):
    return jnp.asarray(np.random.RandomState(seed).uniform(lo, hi, shape),
                       jnp.float32)


@pytest.mark.parametrize("shape", [(1, 8), (4, 64), (64, 512), (128, 100)])
@pytest.mark.parametrize("use_vexp", [True, False])
def test_close_to_oracle(shape, use_vexp):
    x = rand(shape, seed=shape[1])
    got = np.asarray(softmax_pallas(x, use_vexp=use_vexp).astype(jnp.float32))
    want = np.asarray(softmax_ref(x))
    # bf16 path carries ~2^-8 quantization + <=1% exp error
    assert np.abs(got - want).max() < 0.01


def test_vexp_mse_matches_paper_order():
    """Paper Table IV: softmax MSE 1.62e-9 (BF16+VEXP). Same order here."""
    x = rand((256, 512), seed=7, lo=-8, hi=8)
    got = np.asarray(softmax_pallas(x, use_vexp=True).astype(jnp.float32))
    want = np.asarray(softmax_ref(x))
    mse = float(np.mean((got - want) ** 2))
    assert mse < 1e-6, f"softmax MSE {mse:.3e}"


@pytest.mark.parametrize("use_vexp", [True, False])
def test_rows_sum_to_one(use_vexp):
    x = rand((32, 256), seed=3)
    got = np.asarray(softmax_pallas(x, use_vexp=use_vexp).astype(jnp.float32))
    assert np.abs(got.sum(-1) - 1.0).max() < 0.02  # bf16 recip-mul norm
    assert (got >= 0).all()


def test_shift_invariance():
    """softmax(x) == softmax(x + c): max-subtraction must make the kernel
    invariant to row-wise shifts (the numerical-stability property)."""
    # values on a 0.5 grid in [-8, 8) stay exactly representable in bf16
    # after a +64 shift (quantum at 64..128 is 0.5), isolating the kernel's
    # max-subtraction from input quantization effects.
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randint(-16, 16, (8, 128)) * 0.5, jnp.float32)
    a = np.asarray(softmax_pallas(x).astype(jnp.float32))
    b = np.asarray(softmax_pallas(x + 64.0).astype(jnp.float32))
    assert np.abs(a - b).max() < 1e-6


def test_extreme_negative_rows():
    """Rows dominated by one large value must not NaN under VEXP."""
    x = np.full((4, 64), -80.0, np.float32)
    x[:, 0] = 10.0
    got = np.asarray(softmax_pallas(jnp.asarray(x)).astype(jnp.float32))
    assert np.isfinite(got).all()
    assert np.abs(got[:, 0] - 1.0).max() < 1e-2


def test_block_rows_partition_invariance():
    """Tiling must not change results: block sizes are an implementation
    detail (SPM/VMEM capacity), never a numeric one."""
    x = rand((64, 128), seed=9)
    a = np.asarray(softmax_pallas(x, block_rows=8).astype(jnp.float32))
    b = np.asarray(softmax_pallas(x, block_rows=64).astype(jnp.float32))
    assert np.array_equal(a, b)


def test_rows_matches_pallas():
    x = rand((16, 64), seed=11)
    a = np.asarray(softmax_rows(x).astype(jnp.float32))
    b = np.asarray(softmax_pallas(x).astype(jnp.float32))
    assert np.array_equal(a, b)


def test_1d_input():
    x = rand((100,), seed=13)
    got = np.asarray(softmax_pallas(x).astype(jnp.float32))
    assert got.shape == (100,)
    assert abs(got.sum() - 1.0) < 0.02


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(1, 48), cols=st.integers(2, 300),
       seed=st.integers(0, 10000), use_vexp=st.booleans())
def test_hypothesis_sweep(rows, cols, seed, use_vexp):
    x = rand((rows, cols), seed=seed)
    got = np.asarray(softmax_pallas(x, use_vexp=use_vexp)
                     .astype(jnp.float32))
    want = np.asarray(softmax_ref(x))
    assert got.shape == want.shape
    assert np.isfinite(got).all()
    assert np.abs(got - want).max() < 0.015
