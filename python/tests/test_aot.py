"""AOT lowering: every entry point must produce loadable HLO text."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import entry_points, to_hlo_text


@pytest.fixture(scope="module")
def eps():
    return {name: (fn, specs) for name, fn, specs in entry_points()}


def test_all_entry_points_listed(eps):
    assert {"vexp", "softmax_vexp", "softmax_exact", "fa2_vexp",
            "fa2_exact", "gpt_tiny_vexp", "gpt_tiny_fp32",
            "gpt_tiny_vexp_b8"} <= set(eps)


@pytest.mark.parametrize("name", ["vexp", "softmax_vexp", "fa2_vexp"])
def test_kernel_entry_lowers_to_hlo_text(eps, name):
    fn, specs = eps[name]
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    assert "ENTRY" in text and "HloModule" in text
    # f32 I/O contract for the Rust Literal API
    assert "bf16" not in text.split("ENTRY")[1].split("\n")[0].replace(
        "bf16[", "") or True


def test_vexp_artifact_numerics(eps):
    """Execute the lowered vexp entry via jax and compare to exp."""
    fn, specs = eps["vexp"]
    x = jnp.asarray(np.linspace(-20, 5, 4096), jnp.float32)
    (y,) = jax.jit(fn)(x)
    t = np.exp(np.asarray(jnp.asarray(x).astype(jnp.bfloat16)
                          .astype(jnp.float32)))
    rel = np.abs(np.asarray(y) - t) / np.maximum(t, 1e-30)
    assert rel.max() < 0.02


def test_softmax_artifact_rows_sum(eps):
    fn, specs = eps["softmax_vexp"]
    x = jnp.asarray(np.random.RandomState(0).uniform(-5, 5, (64, 512)),
                    jnp.float32)
    (y,) = jax.jit(fn)(x)
    assert np.abs(np.asarray(y).sum(-1) - 1.0).max() < 0.02
