"""AOT compile path: lower every Layer-2 entry point to HLO *text*.

HLO text (NOT ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the Rust ``xla`` crate) rejects; the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py and README gotchas.

Outputs (all under ``artifacts/``):
  *.hlo.txt          one per entry point (f32/i32 I/O only — the Rust
                     Literal API speaks f32/i32; BF16 casts live inside)
  vexp_golden.bin    65536 u16 VEXP outputs, index = input bit pattern
                     (the Rust exhaustive cross-check, see rust/src/vexp)
  theta_random.bin   random-init flat parameter vector for the tiny model
  manifest.json      artifact index: entry point -> input/output shapes

Run via ``make artifacts``; Python never runs after this step.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.flash_attention import flash_attention_pallas
from .kernels.softmax import softmax_pallas
from .kernels.vexp import vexp_numpy_bits, vexp_pallas
from .model import TINY, forward_flat, init_params, flatten_params, num_params


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Entry points (f32 in / f32 out; bf16 internals)
# ---------------------------------------------------------------------------
def ep_vexp(x):
    """Elementwise VEXP over a vector (the VFEXP instruction, en masse)."""
    return (vexp_pallas(x.astype(jnp.bfloat16)).astype(jnp.float32),)


def ep_softmax(x, use_vexp: bool):
    return (softmax_pallas(x, use_vexp=use_vexp).astype(jnp.float32),)


def ep_fa2(q, k, v, use_vexp: bool):
    return (flash_attention_pallas(q, k, v, use_vexp=use_vexp)
            .astype(jnp.float32),)


def ep_model(tokens, theta, mode: str):
    return (forward_flat(tokens, theta, TINY, mode=mode),)


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def entry_points():
    """(name, fn, example_args) for every artifact."""
    n_theta = num_params(TINY)
    f = jnp.float32
    i = jnp.int32
    return [
        ("vexp", ep_vexp, [_spec((4096,), f)]),
        ("softmax_vexp", functools.partial(ep_softmax, use_vexp=True),
         [_spec((64, 512), f)]),
        ("softmax_exact", functools.partial(ep_softmax, use_vexp=False),
         [_spec((64, 512), f)]),
        ("fa2_vexp", functools.partial(ep_fa2, use_vexp=True),
         [_spec((128, 64), f), _spec((256, 64), f), _spec((256, 64), f)]),
        ("fa2_exact", functools.partial(ep_fa2, use_vexp=False),
         [_spec((128, 64), f), _spec((256, 64), f), _spec((256, 64), f)]),
        ("gpt_tiny_vexp", functools.partial(ep_model, mode="bf16_exp"),
         [_spec((1, 128), i), _spec((n_theta,), f)]),
        ("gpt_tiny_fp32", functools.partial(ep_model, mode="fp32"),
         [_spec((1, 128), i), _spec((n_theta,), f)]),
        ("gpt_tiny_vexp_b8", functools.partial(ep_model, mode="bf16_exp"),
         [_spec((8, 128), i), _spec((n_theta,), f)]),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated entry-point names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"model_config": {
        "vocab": TINY.vocab, "d_model": TINY.d_model, "n_heads": TINY.n_heads,
        "n_layers": TINY.n_layers, "d_ff": TINY.d_ff, "max_seq": TINY.max_seq,
        "n_params": num_params(TINY),
    }, "entry_points": {}}

    for name, fn, specs in entry_points():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entry_points"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [{"shape": list(s.shape), "dtype": str(s.dtype)}
                       for s in specs],
        }
        print(f"wrote {path} ({len(text)} chars)")

    # Exhaustive golden table: Rust replays all 2^16 BF16 inputs against it.
    golden = vexp_numpy_bits(np.arange(65536, dtype=np.uint32).astype(np.uint16))
    gpath = os.path.join(args.out_dir, "vexp_golden.bin")
    golden.astype("<u2").tofile(gpath)
    print(f"wrote {gpath} (65536 entries)")

    # Random-init theta so the Rust e2e example runs before training exists.
    params = init_params(TINY, jax.random.PRNGKey(0))
    theta = flatten_params(params, TINY)
    tpath = os.path.join(args.out_dir, "theta_random.bin")
    theta.astype("<f4").tofile(tpath)
    print(f"wrote {tpath} ({theta.size} f32)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
