"""Build-time training of the tiny transformer (Table II substitution).

The paper evaluates pre-trained GPT-2/ViT checkpoints; we have no network
access and no checkpoints, so we train a ~10.7M-parameter decoder from
scratch on a *structured synthetic corpus* (modular-arithmetic sentences
over a 64-symbol alphabet) and then replay the paper's ablation:

    FP32 softmax  vs  BF16 softmax (exact exp)  vs  BF16 + VEXP

measuring held-out perplexity for each. The claim being reproduced is
*shape*, not absolute numbers: BF16 ~ FP32 and BF16+VEXP ~ BF16
(paper Table II: accuracy loss < 0.1 %).

Outputs:
  artifacts/theta.bin             trained flat parameter vector (f32)
  artifacts/accuracy_table.json   the Table-II analogue
  artifacts/train_log.json        loss curve (consumed by EXPERIMENTS.md)
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from .model import TINY, flatten_params, forward, init_params, loss_fn

VOCAB = TINY.vocab
SEQ = TINY.max_seq


# ---------------------------------------------------------------------------
# Synthetic corpus: modular-arithmetic sentences, e.g. "12+45=57;" with
# digits/operators mapped into a 64-symbol alphabet. Structured enough that
# a trained model reaches perplexity far below uniform (64), so numeric
# perturbations of attention are observable in the metric.
# ---------------------------------------------------------------------------
D0 = 0            # symbols 0..9: digits
PLUS, TIMES, EQ, SEP = 10, 11, 12, 13
NOISE0 = 14       # 14..63: filler words for variety


def make_corpus(n_tokens: int, seed: int) -> np.ndarray:
    rng = np.random.RandomState(seed)
    out: list[int] = []
    while len(out) < n_tokens:
        a, b = rng.randint(0, 100, 2)
        op = rng.randint(0, 2)
        c = (a + b) % 100 if op == 0 else (a * b) % 100
        out += [D0 + a // 10, D0 + a % 10,
                PLUS if op == 0 else TIMES,
                D0 + b // 10, D0 + b % 10, EQ,
                D0 + c // 10, D0 + c % 10, SEP]
        if rng.rand() < 0.3:  # interleave a short "word"
            w = rng.randint(NOISE0, VOCAB, rng.randint(2, 5))
            out += list(w) + [SEP]
    return np.asarray(out[:n_tokens], np.int32)


def batches(corpus: np.ndarray, batch: int, steps: int, seed: int):
    rng = np.random.RandomState(seed)
    n = len(corpus) - SEQ - 1
    for _ in range(steps):
        idx = rng.randint(0, n, batch)
        yield np.stack([corpus[i:i + SEQ + 1] for i in idx])


# ---------------------------------------------------------------------------
# Adam (hand-rolled; no optax dependency on the build path)
# ---------------------------------------------------------------------------
def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr=3e-4, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_s = 1.0 / (1 - b1 ** t)
    vhat_s = 1.0 / (1 - b2 ** t)
    params = jax.tree.map(
        lambda p, m, v: p - lr * (m * mhat_s) / (jnp.sqrt(v * vhat_s) + eps),
        params, m, v)
    return params, {"m": m, "v": v, "t": t}


def perplexity(params, tokens, mode: str, batch: int = 8) -> float:
    """Mean held-out perplexity under the given softmax numerics."""
    total, count = 0.0, 0
    f = jax.jit(lambda p, t: loss_fn(p, t, TINY, mode))
    for i in range(0, len(tokens) - batch + 1, batch):
        total += float(f(params, jnp.asarray(tokens[i:i + batch]))) * batch
        count += batch
    return float(np.exp(total / max(count, 1)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--eval-seqs", type=int, default=64)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    corpus = make_corpus(400_000, seed=0)
    held = make_corpus(80_000, seed=1)
    eval_tokens = np.stack([held[i * (SEQ + 1):(i + 1) * (SEQ + 1)]
                            for i in range(args.eval_seqs)])

    params = init_params(TINY, jax.random.PRNGKey(0))
    opt = adam_init(params)
    step_fn = jax.jit(jax.value_and_grad(
        lambda p, t: loss_fn(p, t, TINY, "fp32")))

    log = []
    t0 = time.time()
    for step, tok in enumerate(batches(corpus, args.batch, args.steps, 2)):
        loss, grads = step_fn(params, jnp.asarray(tok))
        params, opt = adam_step(params, grads, opt)
        if step % 10 == 0 or step == args.steps - 1:
            log.append({"step": step, "loss": float(loss),
                        "elapsed_s": time.time() - t0})
            print(f"step {step:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)")

    theta = flatten_params(params, TINY)
    theta.astype("<f4").tofile(os.path.join(args.out_dir, "theta.bin"))

    table = {}
    for mode, label in [("fp32", "FP32"), ("bf16", "BF16"),
                        ("bf16_exp", "BF16 EXP")]:
        ppl = perplexity(params, eval_tokens, mode)
        table[label] = {"perplexity": ppl}
        print(f"{label:9s} perplexity {ppl:.4f}")

    with open(os.path.join(args.out_dir, "accuracy_table.json"), "w") as f:
        json.dump({"dataset": "synthetic modular-arithmetic corpus",
                   "model": "tiny GPT (10.7M params)",
                   "metric": "perplexity (lower is better)",
                   "results": table}, f, indent=2)
    with open(os.path.join(args.out_dir, "train_log.json"), "w") as f:
        json.dump(log, f, indent=2)
    print("wrote theta.bin, accuracy_table.json, train_log.json")


if __name__ == "__main__":
    main()
