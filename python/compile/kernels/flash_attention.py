"""FlashAttention-2 forward with partial softmax (paper §III-B/§IV-D).

The kernel tiles K/V along the sequence axis and maintains running
row statistics (max ``m`` and exp-sum ``l``) exactly as FlashAttention-2
does on the Snitch SPM. The exponential inside the partial softmax is
pluggable: exact (f32 exp) or VEXP (the paper's hardware approximation).

On TPU this maps to: Q block resident in VMEM (BlockSpec over query rows),
K/V streamed block-by-block HBM->VMEM (the fori_lax loop below), QK^T and
PV on the MXU, the partial softmax on the VPU — the same split the paper
implements with the DMA double buffer + FPU + EXP block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .vexp import vexp


def _exp_fn(x, use_vexp: bool):
    if use_vexp:
        return vexp(x.astype(jnp.bfloat16)).astype(jnp.float32)
    return jnp.exp(x.astype(jnp.float32))


def flash_attention_rows(q, k, v, block_k: int = 64, use_vexp: bool = True,
                         scale: float | None = None):
    """Single-head FlashAttention-2 over (Sq, d), (Sk, d), (Sk, d).

    Pure-jnp tiled implementation (the structural twin of the Rust kernel in
    ``rust/src/kernels/flash_attention.rs``); used as the L2 building block
    and as a readable reference for the Pallas kernel below.
    """
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    sq, d = q.shape
    sk = k.shape[0]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.float32(d))
    bk = min(block_k, sk)
    if sk % bk != 0:
        bk = sk
    nblk = sk // bk

    def body(i, carry):
        acc, m, l = carry
        kb = jax.lax.dynamic_slice_in_dim(k, i * bk, bk, axis=0)
        vb = jax.lax.dynamic_slice_in_dim(v, i * bk, bk, axis=0)
        s = (q @ kb.T) * scale                        # (Sq, bk) on the MXU
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))   # partial MAX
        p = _exp_fn(s - m_new[:, None], use_vexp)     # partial EXP
        corr = _exp_fn(m - m_new, use_vexp)           # rescale old stats
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[:, None] + p @ vb            # PV on the MXU
        return acc, m_new, l

    acc = jnp.zeros((sq, d), jnp.float32)
    m0 = jnp.full((sq,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((sq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nblk, body, (acc, m0, l0))
    return acc / l[:, None]                           # NORM: one div per row


def _fa2_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, use_vexp: bool,
                scale: float):
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    sq, d = q.shape
    sk = k.shape[0]
    nblk = sk // block_k

    def body(i, carry):
        acc, m, l = carry
        kb = jax.lax.dynamic_slice_in_dim(k, i * block_k, block_k, axis=0)
        vb = jax.lax.dynamic_slice_in_dim(v, i * block_k, block_k, axis=0)
        s = (q @ kb.T) * scale
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = _exp_fn(s - m_new[:, None], use_vexp)
        corr = _exp_fn(m - m_new, use_vexp)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[:, None] + p @ vb
        return acc, m_new, l

    acc = jnp.zeros((sq, d), jnp.float32)
    m0 = jnp.full((sq,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((sq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nblk, body, (acc, m0, l0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, block_q: int = 64, block_k: int = 64,
                           use_vexp: bool = True, scale: float | None = None):
    """Single-head FlashAttention-2 as a Pallas kernel (interpret mode).

    Grid over query blocks; K and V are passed whole per program (streamed
    inside the kernel via the fori loop) so running statistics live in
    registers for the lifetime of a Q block.
    """
    q = q.astype(jnp.bfloat16)
    k = k.astype(jnp.bfloat16)
    v = v.astype(jnp.bfloat16)
    sq, d = q.shape
    sk = k.shape[0]
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    bq = min(block_q, sq)
    if sq % bq != 0:
        bq = sq
    bk = min(block_k, sk)
    if sk % bk != 0:
        bk = sk
    kernel = functools.partial(_fa2_kernel, block_k=bk, use_vexp=use_vexp,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((sq, d), jnp.bfloat16),
        grid=(sq // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((sk, d), lambda i: (0, 0)),
            pl.BlockSpec((sk, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        interpret=True,
    )(q, k, v)


def mha_flash(q, k, v, use_vexp: bool = True, block_q: int = 64,
              block_k: int = 64):
    """Multi-head wrapper: q/k/v are (H, S, d); vmap over heads.

    This is the per-cluster unit of work in the paper's §V-D mapping
    (one attention head per Snitch cluster).
    """
    fn = functools.partial(flash_attention_pallas, block_q=block_q,
                           block_k=block_k, use_vexp=use_vexp)
    return jax.vmap(fn)(q, k, v)
