"""Pure-jnp correctness oracles for every Layer-1 kernel.

These run in float32 with exact transcendental functions and define what
"numerically right" means for the Pallas kernels and for the Rust
simulator's host-level references.
"""

from __future__ import annotations

import jax.numpy as jnp


def exp_ref(x):
    """Exact exponential in f32 (glibc-equivalent for our error metrics)."""
    return jnp.exp(x.astype(jnp.float32))


def softmax_ref(x, axis: int = -1):
    """Numerically stable softmax with max subtraction (paper §III-B)."""
    x = x.astype(jnp.float32)
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def attention_ref(q, k, v, scale: float | None = None):
    """Unfused exact attention: softmax(q k^T / sqrt(d)) v in f32."""
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.float32(d))
    s = jnp.einsum("...qd,...kd->...qk", q, k) * scale
    p = softmax_ref(s, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", p, v)


def flash_attention_ref(q, k, v, scale: float | None = None):
    """FlashAttention is exact attention; the oracle is the unfused form."""
    return attention_ref(q, k, v, scale)


def gelu_ref(x):
    """tanh-approximation GELU (what the transformer FFN uses)."""
    x = x.astype(jnp.float32)
    c = jnp.sqrt(jnp.float32(2.0 / jnp.pi))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def layernorm_ref(x, gamma, beta, eps: float = 1e-5):
    """LayerNorm over the last axis in f32."""
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta
