"""Row softmax kernels (paper §IV-C): MAX -> EXP -> NORM.

Two Pallas variants:
  * ``softmax_pallas(..., use_vexp=True)``  — the paper's optimized kernel:
    max-subtract, VEXP exponentiation, reciprocal-multiply normalization.
  * ``use_vexp=False`` — identical structure with the exact exponential
    (the "BF16 baseline numeric" configuration of Table II).

The row axis is the grid; each block holds ``block_rows`` full rows in VMEM
so the row-wise reductions (max, sum) never leave the block — the VMEM
analogue of keeping a row resident in the Snitch SPM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .vexp import vexp


def softmax_rows(x, use_vexp: bool = True):
    """Non-Pallas reference structure of the optimized kernel (BF16 math)."""
    xb = x.astype(jnp.bfloat16)
    m = jnp.max(xb, axis=-1, keepdims=True)
    t = (xb - m).astype(jnp.bfloat16)
    e = vexp(t) if use_vexp else jnp.exp(t.astype(jnp.float32)).astype(jnp.bfloat16)
    s = jnp.sum(e.astype(jnp.float32), axis=-1, keepdims=True)
    recip = (1.0 / s).astype(jnp.bfloat16)           # one division per row
    return (e * recip).astype(jnp.bfloat16)


def _softmax_kernel_vexp(x_ref, o_ref):
    x = x_ref[...].astype(jnp.bfloat16)
    m = jnp.max(x, axis=-1, keepdims=True)           # MAX  (VFMAX loop)
    e = vexp((x - m).astype(jnp.bfloat16))           # EXP  (VFEXP loop)
    s = jnp.sum(e.astype(jnp.float32), axis=-1, keepdims=True)
    recip = (1.0 / s).astype(jnp.bfloat16)           # single FDIV
    o_ref[...] = (e * recip).astype(jnp.bfloat16)    # NORM (VFMUL loop)


def _softmax_kernel_exact(x_ref, o_ref):
    x = x_ref[...].astype(jnp.bfloat16)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp((x - m).astype(jnp.float32)).astype(jnp.bfloat16)
    s = jnp.sum(e.astype(jnp.float32), axis=-1, keepdims=True)
    recip = (1.0 / s).astype(jnp.bfloat16)
    o_ref[...] = (e * recip).astype(jnp.bfloat16)


def softmax_pallas(x, use_vexp: bool = True, block_rows: int = 64):
    """Fused row softmax as a Pallas kernel over (rows, cols) bf16 input."""
    x = x.astype(jnp.bfloat16)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    rows, cols = x.shape
    br = min(block_rows, rows)
    if rows % br != 0:
        br = rows
    kernel = _softmax_kernel_vexp if use_vexp else _softmax_kernel_exact
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.bfloat16),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        interpret=True,
    )(x)
    return out[0] if squeeze else out
