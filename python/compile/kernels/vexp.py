"""Bit-exact model of the VEXP BF16 exponential block (paper Fig. 3c-e).

This is Layer-1's numeric ground truth: the same fixed-point pipeline is
implemented in Rust (``rust/src/vexp``) and the two are cross-checked
exhaustively over all 2^16 BF16 bit patterns (``make artifacts`` dumps the
golden table; ``cargo test`` replays it).

Pipeline (DESIGN.md §6):
  exps(x):  M = 1.m (Q1.7);  P = M * log2(e) (Q1.15) -> Q2.22;
            r = round_half_up(P >> (142 - e)) -> Q8.7 int/frac split.
  P(x):     two-branch fixed-point mantissa correction,
            alpha=0.21875 beta=0.4375 gamma1=3.296875 gamma2=2.171875,
            with 1-x approximated by bitwise not(x).

Everything here is vectorized uint32 arithmetic so the identical code runs
under numpy, plain jnp, and inside a Pallas kernel (interpret=True).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# ---------------------------------------------------------------------------
# Fixed-point constants (locked; see DESIGN.md §6 and rust/src/vexp/consts.rs)
# ---------------------------------------------------------------------------
LOG2E_Q15 = 47274  # round(log2(e) * 2^15): Q1.15
ALPHA_Q7 = 28      # 0.21875 * 128
BETA_Q7 = 56       # 0.4375  * 128
GAMMA1_Q7 = 422    # 3.296875 * 128
GAMMA2_Q7 = 278    # 2.171875 * 128
SHIFT_BIAS = 142   # Q2.22 -> Q8.7 alignment: shift = 142 - exponent
MAX_SHIFT = 40     # beyond this the product is fully shifted out -> r = 0


def _poly_q7(rf):
    """Mantissa-correction polynomial P(frac) on a 7-bit fraction (Fig. 3e).

    rf: uint32 array of Q0.7 fractions in [0, 128). Returns uint32 in [0, 128).
    """
    rf = rf.astype(jnp.uint32)
    lo = rf < 64
    # branch [0, 0.5): p = rnd14(alpha * f * (f + gamma1))
    t_lo = rf * (rf + GAMMA1_Q7) * ALPHA_Q7            # Q2.21
    p_lo = (t_lo + (1 << 13)) >> 14                    # Q0.7, round-half-up
    # branch [0.5, 1): p = not(rnd14(beta * not(f) * (f + gamma2)))
    t_hi = (127 - rf) * (rf + GAMMA2_Q7) * BETA_Q7     # Q2.21
    q_hi = (t_hi + (1 << 13)) >> 14
    p_hi = 127 - q_hi
    p = jnp.where(lo, p_lo, p_hi)
    return jnp.minimum(p, 127).astype(jnp.uint32)


def vexp_bits(bits):
    """Bit-exact VEXP on BF16 bit patterns.

    bits: uint16/uint32 array of BF16 encodings. Returns uint16 BF16 encodings
    of exp(x) under the paper's approximation.
    """
    b = bits.astype(jnp.uint32)
    s = (b >> 15) & 0x1
    e = (b >> 7) & 0xFF
    m = b & 0x7F

    # --- exps(x) stage -----------------------------------------------------
    sig = (0x80 | m).astype(jnp.uint32)                # Q1.7 significand
    prod = sig * jnp.uint32(LOG2E_Q15)                 # Q2.22, <= 24 bits
    shift = SHIFT_BIAS - e.astype(jnp.int32)           # to Q8.7
    sh = jnp.clip(shift, 1, MAX_SHIFT).astype(jnp.uint32)
    r = (prod + (jnp.uint32(1) << (sh - 1))) >> sh     # round-half-up
    r = jnp.where(shift <= 0, jnp.uint32(1 << 20), r)  # guaranteed overflow
    r = jnp.where(shift > MAX_SHIFT, jnp.uint32(0), r)

    ri = r >> 7
    rf = r & 0x7F
    # negative arguments: floor crosses down one, fraction complements
    ri_n = ri + (rf != 0).astype(jnp.uint32)
    rf_n = jnp.where(rf != 0, 128 - rf, 0).astype(jnp.uint32) & 0x7F
    ri = jnp.where(s == 1, ri_n, ri)
    rf = jnp.where(s == 1, rf_n, rf)

    eo = jnp.where(
        s == 1,
        jnp.int32(127) - ri.astype(jnp.int32),
        jnp.int32(127) + ri.astype(jnp.int32),
    )

    # --- P(x) stage --------------------------------------------------------
    mant = _poly_q7(rf)

    out = (jnp.clip(eo, 0, 255).astype(jnp.uint32) << 7) | mant
    out = jnp.where(eo >= 255, jnp.uint32(0x7F80), out)   # overflow -> +inf
    out = jnp.where(eo <= 0, jnp.uint32(0), out)          # underflow -> 0 (FTZ)

    # --- specials ----------------------------------------------------------
    is_nan = (e == 0xFF) & (m != 0)
    is_inf = (e == 0xFF) & (m == 0)
    is_zero = e == 0                                       # zero/subnormal FTZ
    out = jnp.where(is_zero, jnp.uint32(0x3F80), out)      # exp(~0) = 1.0
    out = jnp.where(is_inf & (s == 0), jnp.uint32(0x7F80), out)
    out = jnp.where(is_inf & (s == 1), jnp.uint32(0), out)
    out = jnp.where(is_nan, b | 0x40, out)                 # quiet the NaN
    return out.astype(jnp.uint16)


def bf16_to_bits(x):
    """Reinterpret a bfloat16 array as uint16 bit patterns."""
    return jax.lax.bitcast_convert_type(x.astype(jnp.bfloat16), jnp.uint16)


def bits_to_bf16(b):
    """Reinterpret uint16 bit patterns as bfloat16 values."""
    return jax.lax.bitcast_convert_type(b.astype(jnp.uint16), jnp.bfloat16)


def vexp(x):
    """VEXP on values: bfloat16 in, bfloat16 out (the VFEXP instruction)."""
    return bits_to_bf16(vexp_bits(bf16_to_bits(x)))


# ---------------------------------------------------------------------------
# Pallas kernel: elementwise VEXP over a VMEM block.
# ---------------------------------------------------------------------------
def _vexp_kernel(x_ref, o_ref):
    o_ref[...] = vexp(x_ref[...])


def vexp_pallas(x, block_rows: int = 256):
    """Elementwise VEXP as a Pallas kernel (interpret mode on CPU).

    The row axis is tiled into VMEM blocks of ``block_rows`` rows; each block
    is pure VPU integer work (no MXU), mirroring the paper's "EXP on the
    programmable unit, GEMM on the big unit" split.
    """
    x = x.astype(jnp.bfloat16)
    if x.ndim == 1:
        return vexp_pallas(x[None, :], block_rows)[0]
    rows, cols = x.shape
    br = min(block_rows, rows)
    if rows % br != 0:
        br = rows  # fall back to a single block for ragged shapes
    return pl.pallas_call(
        _vexp_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.bfloat16),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        interpret=True,
    )(x)


def vexp_numpy_bits(bits: np.ndarray) -> np.ndarray:
    """Pure-numpy twin of :func:`vexp_bits` (used for golden-table dumps)."""
    b = bits.astype(np.uint32)
    s = (b >> 15) & 0x1
    e = (b >> 7) & 0xFF
    m = b & 0x7F

    sig = (0x80 | m).astype(np.uint64)
    prod = sig * np.uint64(LOG2E_Q15)
    shift = SHIFT_BIAS - e.astype(np.int64)
    sh = np.clip(shift, 1, MAX_SHIFT).astype(np.uint64)
    r = ((prod + (np.uint64(1) << (sh - np.uint64(1)))) >> sh).astype(np.uint32)
    r = np.where(shift <= 0, np.uint32(1 << 20), r)
    r = np.where(shift > MAX_SHIFT, np.uint32(0), r)

    ri = r >> 7
    rf = r & 0x7F
    ri_n = ri + (rf != 0).astype(np.uint32)
    rf_n = np.where(rf != 0, 128 - rf, 0).astype(np.uint32) & 0x7F
    ri = np.where(s == 1, ri_n, ri)
    rf = np.where(s == 1, rf_n, rf)
    eo = np.where(s == 1, 127 - ri.astype(np.int64), 127 + ri.astype(np.int64))

    lo = rf < 64
    t_lo = rf.astype(np.uint64) * (rf + GAMMA1_Q7) * ALPHA_Q7
    p_lo = (t_lo + (1 << 13)) >> 14
    t_hi = (127 - rf).astype(np.uint64) * (rf + GAMMA2_Q7) * BETA_Q7
    p_hi = 127 - ((t_hi + (1 << 13)) >> 14)
    mant = np.minimum(np.where(lo, p_lo, p_hi), 127).astype(np.uint32)

    out = (np.clip(eo, 0, 255).astype(np.uint32) << 7) | mant
    out = np.where(eo >= 255, np.uint32(0x7F80), out)
    out = np.where(eo <= 0, np.uint32(0), out)

    is_nan = (e == 0xFF) & (m != 0)
    is_inf = (e == 0xFF) & (m == 0)
    is_zero = e == 0
    out = np.where(is_zero, np.uint32(0x3F80), out)
    out = np.where(is_inf & (s == 0), np.uint32(0x7F80), out)
    out = np.where(is_inf & (s == 1), np.uint32(0), out)
    out = np.where(is_nan, b | 0x40, out)
    return out.astype(np.uint16)
