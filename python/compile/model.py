"""Layer-2: decoder-only transformer with pluggable softmax numerics.

The attention softmax can run in three configurations matching Table II of
the paper: ``fp32`` (exact), ``bf16`` (BF16 math, exact exp) and
``bf16_exp`` (BF16 math + the VEXP approximation). Everything else stays
in float32 so the measured accuracy delta is attributable to the
exponential approximation alone — exactly the paper's ablation.

The forward pass is also exported with a *flat* parameter vector
(``forward_flat``) so the AOT artifact takes two inputs (tokens, theta)
and the Rust runtime can feed trained weights as a single PJRT literal.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.flash_attention import flash_attention_rows
from .kernels.ref import gelu_ref, layernorm_ref
from .kernels.vexp import vexp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer hyper-parameters (GPT-2-style decoder)."""

    vocab: int = 64
    d_model: int = 384
    n_heads: int = 6
    n_layers: int = 6
    d_ff: int = 1536
    max_seq: int = 128

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


TINY = ModelConfig()  # ~10.7M params: the build-time trainable stand-in


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    """Initialize a parameter pytree with GPT-2-style scaling."""
    keys = jax.random.split(key, 4 + cfg.n_layers)
    s = 0.02
    params: Dict[str, Any] = {
        "wte": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * s,
        "wpe": jax.random.normal(keys[1], (cfg.max_seq, cfg.d_model)) * s,
        "lnf_g": jnp.ones((cfg.d_model,)),
        "lnf_b": jnp.zeros((cfg.d_model,)),
        "layers": [],
    }
    out_s = s / np.sqrt(2 * cfg.n_layers)
    for i in range(cfg.n_layers):
        k = jax.random.split(keys[4 + i], 6)
        params["layers"].append({
            "ln1_g": jnp.ones((cfg.d_model,)),
            "ln1_b": jnp.zeros((cfg.d_model,)),
            "wqkv": jax.random.normal(k[0], (cfg.d_model, 3 * cfg.d_model)) * s,
            "bqkv": jnp.zeros((3 * cfg.d_model,)),
            "wo": jax.random.normal(k[1], (cfg.d_model, cfg.d_model)) * out_s,
            "bo": jnp.zeros((cfg.d_model,)),
            "ln2_g": jnp.ones((cfg.d_model,)),
            "ln2_b": jnp.zeros((cfg.d_model,)),
            "w1": jax.random.normal(k[2], (cfg.d_model, cfg.d_ff)) * s,
            "b1": jnp.zeros((cfg.d_ff,)),
            "w2": jax.random.normal(k[3], (cfg.d_ff, cfg.d_model)) * out_s,
            "b2": jnp.zeros((cfg.d_model,)),
        })
    return params


def _attention(q, k, v, mode: str):
    """Causal attention for one head; ``mode`` selects softmax numerics."""
    s_q, d = q.shape
    s_k = k.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    scores = (q @ k.T) * scale
    mask = jnp.tril(jnp.ones((s_q, s_k), bool), k=s_k - s_q)
    scores = jnp.where(mask, scores, -jnp.inf)
    if mode == "fp32":
        m = jnp.max(scores, axis=-1, keepdims=True)
        e = jnp.exp(scores - m)
        p = e / jnp.sum(e, axis=-1, keepdims=True)
    elif mode == "bf16":
        sb = scores.astype(jnp.bfloat16)
        m = jnp.max(sb, axis=-1, keepdims=True)
        e = jnp.exp((sb - m).astype(jnp.float32)).astype(jnp.bfloat16)
        l = jnp.sum(e.astype(jnp.float32), axis=-1, keepdims=True)
        p = (e * (1.0 / l).astype(jnp.bfloat16)).astype(jnp.float32)
    elif mode == "bf16_exp":
        sb = scores.astype(jnp.bfloat16)
        m = jnp.max(sb, axis=-1, keepdims=True)
        e = vexp((sb - m).astype(jnp.bfloat16))
        l = jnp.sum(e.astype(jnp.float32), axis=-1, keepdims=True)
        p = (e.astype(jnp.float32) * (1.0 / l))
    else:
        raise ValueError(f"unknown softmax mode {mode!r}")
    return p.astype(jnp.float32) @ v


def _block(x, lp, cfg: ModelConfig, mode: str):
    """One pre-LN transformer block."""
    h = layernorm_ref(x, lp["ln1_g"], lp["ln1_b"])
    qkv = h @ lp["wqkv"] + lp["bqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    seq = x.shape[0]
    dh = cfg.d_head
    q = q.reshape(seq, cfg.n_heads, dh).transpose(1, 0, 2)
    k = k.reshape(seq, cfg.n_heads, dh).transpose(1, 0, 2)
    v = v.reshape(seq, cfg.n_heads, dh).transpose(1, 0, 2)
    attn = jax.vmap(lambda qq, kk, vv: _attention(qq, kk, vv, mode))(q, k, v)
    attn = attn.transpose(1, 0, 2).reshape(seq, cfg.d_model)
    x = x + attn @ lp["wo"] + lp["bo"]
    h = layernorm_ref(x, lp["ln2_g"], lp["ln2_b"])
    x = x + gelu_ref(h @ lp["w1"] + lp["b1"]) @ lp["w2"] + lp["b2"]
    return x


def forward(params, tokens, cfg: ModelConfig, mode: str = "fp32"):
    """Logits for a batch of token sequences: (B, S) int32 -> (B, S, V)."""

    def single(toks):
        seq = toks.shape[0]
        x = params["wte"][toks] + params["wpe"][:seq]
        for lp in params["layers"]:
            x = _block(x, lp, cfg, mode)
        x = layernorm_ref(x, params["lnf_g"], params["lnf_b"])
        return x @ params["wte"].T

    return jax.vmap(single)(tokens)


def loss_fn(params, tokens, cfg: ModelConfig, mode: str = "fp32"):
    """Next-token cross-entropy (mean over all positions)."""
    logits = forward(params, tokens[:, :-1], cfg, mode)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Flat-parameter packing for AOT export (theta: single f32 vector input).
# ---------------------------------------------------------------------------
def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) list defining the theta layout."""
    spec: List[Tuple[str, Tuple[int, ...]]] = [
        ("wte", (cfg.vocab, cfg.d_model)),
        ("wpe", (cfg.max_seq, cfg.d_model)),
        ("lnf_g", (cfg.d_model,)),
        ("lnf_b", (cfg.d_model,)),
    ]
    for i in range(cfg.n_layers):
        spec += [
            (f"l{i}.ln1_g", (cfg.d_model,)),
            (f"l{i}.ln1_b", (cfg.d_model,)),
            (f"l{i}.wqkv", (cfg.d_model, 3 * cfg.d_model)),
            (f"l{i}.bqkv", (3 * cfg.d_model,)),
            (f"l{i}.wo", (cfg.d_model, cfg.d_model)),
            (f"l{i}.bo", (cfg.d_model,)),
            (f"l{i}.ln2_g", (cfg.d_model,)),
            (f"l{i}.ln2_b", (cfg.d_model,)),
            (f"l{i}.w1", (cfg.d_model, cfg.d_ff)),
            (f"l{i}.b1", (cfg.d_ff,)),
            (f"l{i}.w2", (cfg.d_ff, cfg.d_model)),
            (f"l{i}.b2", (cfg.d_model,)),
        ]
    return spec


def num_params(cfg: ModelConfig) -> int:
    return sum(int(np.prod(s)) for _, s in param_spec(cfg))


def flatten_params(params, cfg: ModelConfig) -> np.ndarray:
    """Pack the pytree into the theta vector per :func:`param_spec`."""
    flat: Dict[str, Any] = {
        "wte": params["wte"], "wpe": params["wpe"],
        "lnf_g": params["lnf_g"], "lnf_b": params["lnf_b"],
    }
    for i, lp in enumerate(params["layers"]):
        for k, v in lp.items():
            flat[f"l{i}.{k}"] = v
    parts = [np.asarray(flat[name], np.float32).reshape(-1)
             for name, _ in param_spec(cfg)]
    return np.concatenate(parts)


def unflatten_params(theta, cfg: ModelConfig):
    """Inverse of :func:`flatten_params` (traceable: works on tracers)."""
    spec = param_spec(cfg)
    out: Dict[str, Any] = {"layers": [dict() for _ in range(cfg.n_layers)]}
    off = 0
    for name, shape in spec:
        n = int(np.prod(shape))
        t = jax.lax.dynamic_slice_in_dim(theta, off, n).reshape(shape)
        off += n
        if "." in name:
            layer, key = name.split(".")
            out["layers"][int(layer[1:])][key] = t
        else:
            out[name] = t
    return out


def forward_flat(tokens, theta, cfg: ModelConfig, mode: str = "bf16_exp"):
    """AOT entry point: (B,S) int32 tokens + flat theta -> (B,S,V) logits."""
    params = unflatten_params(theta, cfg)
    return forward(params, tokens, cfg, mode)
