//! END-TO-END driver: serve batched inference requests on the trained
//! tiny transformer through the full stack, proving all layers compose:
//!
//!   Pallas VEXP kernel (L1) -> JAX transformer w/ BF16+VEXP attention
//!   (L2) -> HLO text artifact -> Rust PJRT runtime + coordinator (L3).
//!
//! Loads `artifacts/theta.bin` (trained by `make accuracy`; falls back
//! to `theta_random.bin`), runs greedy next-token prediction for a batch
//! of prompts, reports wall-clock latency/throughput, and overlays the
//! 16-cluster simulator estimate of what the same workload costs on the
//! Occamy-style system with and without the VEXP extension.
//!
//! Run: `cargo run --release --example e2e_inference`

use anyhow::{Context, Result};
use std::time::Instant;
use vexp::coordinator::{KernelRates, SystemEstimator, TilePlan};
use vexp::model::TransformerConfig;
use vexp::runtime::pjrt::Input;
use vexp::runtime::Runtime;

const SEQ: usize = 128;
const VOCAB: usize = 64;

fn load_theta(dir: &std::path::Path) -> Result<Vec<f32>> {
    let path = ["theta.bin", "theta_random.bin"]
        .iter()
        .map(|f| dir.join(f))
        .find(|p| p.exists())
        .context("no theta artifact — run `make artifacts` (and `make accuracy`)")?;
    println!("weights: {}", path.display());
    let bytes = std::fs::read(path)?;
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
}

/// A synthetic "prompt": the modular-arithmetic corpus format the tiny
/// model was trained on (see python/compile/train.py).
fn prompt(seed: i32) -> Vec<i32> {
    let (a, b) = ((seed * 7 + 13) % 100, (seed * 31 + 7) % 100);
    let c = (a + b) % 100;
    let sent = [a / 10, a % 10, 10, b / 10, b % 10, 12, c / 10, c % 10, 13];
    (0..SEQ).map(|i| sent[i % sent.len()]).collect()
}

fn main() -> Result<()> {
    let mut rt = Runtime::open("artifacts")?;
    let theta = load_theta(rt.artifact_dir())?;

    // --- single-request latency (batch 1) ------------------------------
    println!("compiling gpt_tiny_vexp (BF16 + VEXP attention)...");
    rt.compile("gpt_tiny_vexp")?;
    let toks = prompt(1);
    let t0 = Instant::now();
    let logits = rt.execute("gpt_tiny_vexp", &[Input::I32(&toks), Input::F32(&theta)])?;
    let lat = t0.elapsed();
    assert_eq!(logits.len(), SEQ * VOCAB);
    println!("batch-1 latency: {:.1} ms", lat.as_secs_f64() * 1e3);

    // --- batched serving (batch 8) --------------------------------------
    rt.compile("gpt_tiny_vexp_b8")?;
    let batch: Vec<i32> = (0..8).flat_map(prompt).collect();
    let t1 = Instant::now();
    let out = rt.execute("gpt_tiny_vexp_b8", &[Input::I32(&batch), Input::F32(&theta)])?;
    let bl = t1.elapsed();
    println!(
        "batch-8 latency: {:.1} ms -> {:.0} tokens/s on the CPU PJRT client",
        bl.as_secs_f64() * 1e3,
        (8 * SEQ) as f64 / bl.as_secs_f64()
    );

    // --- greedy next-token accuracy on the arithmetic task ---------------
    let mut correct = 0;
    let mut total = 0;
    for b in 0..8 {
        let toks = &batch[b * SEQ..(b + 1) * SEQ];
        let lg = &out[b * SEQ * VOCAB..(b + 1) * SEQ * VOCAB];
        for pos in 8..SEQ - 1 {
            let row = &lg[pos * VOCAB..(pos + 1) * VOCAB];
            let arg = row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
            total += 1;
            if arg as i32 == toks[pos + 1] {
                correct += 1;
            }
        }
    }
    println!(
        "greedy next-token accuracy on the synthetic task: {:.1}% ({correct}/{total})",
        100.0 * correct as f64 / total as f64
    );

    // --- what this workload costs on the Occamy-style target -------------
    let cfg = TransformerConfig {
        name: "tiny-GPT", layers: 6, d_model: 384, heads: 6, d_ff: 1536, seq: SEQ as u32,
    };
    let est = SystemEstimator::new(KernelRates::calibrate());
    let (b, o) = est.fig8_pair(&cfg);
    let plan = TilePlan::plan(&cfg);
    println!(
        "16-cluster estimate: baseline {:.3} ms vs VFEXP-optimized {:.3} ms ({:.1}x), \
         energy {:.2} mJ vs {:.2} mJ ({:.1}x); FA-2 tile plan bq={} bk={}",
        b.latency_ms(), o.latency_ms(), b.cycles / o.cycles,
        b.energy_mj(), o.energy_mj(), b.energy_pj / o.energy_pj,
        plan.bq, plan.bk
    );
    Ok(())
}
