//! END-TO-END driver: serve batched inference requests through the full
//! stack, proving all layers compose:
//!
//!   Pallas VEXP kernel (L1) -> JAX transformer w/ BF16+VEXP attention
//!   (L2) -> HLO text artifact -> Rust PJRT runtime (L3, `--features
//!   pjrt`) -> the unified execution engine batching concurrent
//!   requests onto the 16-cluster Occamy-style target.
//!
//! With the PJRT feature + artifacts present, the tiny trained
//! transformer answers real prompts; either way, the engine packs a
//! mixed batch (the tiny GPT plus the paper models) onto the simulated
//! system and reports per-request cost from both backends.
//!
//! Run: `cargo run --release --example e2e_inference`

use std::time::Instant;
use vexp::coordinator::CLUSTERS;
use vexp::error::{Context, Result};
use vexp::exec::{AnalyticBackend, Backend, CycleSimBackend, Engine, Request};
use vexp::model::{TransformerConfig, GPT2_SMALL, VIT_BASE};
use vexp::runtime::pjrt::Input;
use vexp::runtime::Runtime;

const SEQ: usize = 128;
const VOCAB: usize = 64;

const TINY_GPT: TransformerConfig = TransformerConfig {
    name: "tiny-GPT",
    layers: 6,
    d_model: 384,
    heads: 6,
    d_ff: 1536,
    seq: SEQ as u32,
};

fn load_theta(dir: &std::path::Path) -> Result<Vec<f32>> {
    let path = ["theta.bin", "theta_random.bin"]
        .iter()
        .map(|f| dir.join(f))
        .find(|p| p.exists())
        .context("no theta artifact — run `make artifacts` (and `make accuracy`)")?;
    println!("weights: {}", path.display());
    let bytes = std::fs::read(path)?;
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
}

/// A synthetic "prompt": the modular-arithmetic corpus format the tiny
/// model was trained on (see python/compile/train.py).
fn prompt(seed: i32) -> Vec<i32> {
    let (a, b) = ((seed * 7 + 13) % 100, (seed * 31 + 7) % 100);
    let c = (a + b) % 100;
    let sent = [a / 10, a % 10, 10, b / 10, b % 10, 12, c / 10, c % 10, 13];
    (0..SEQ).map(|i| sent[i % sent.len()]).collect()
}

/// The PJRT leg: real execution of the trained tiny transformer.
fn pjrt_leg() -> Result<()> {
    let mut rt = Runtime::open("artifacts")?;
    let theta = load_theta(rt.artifact_dir())?;

    // --- single-request latency (batch 1) ------------------------------
    println!("compiling gpt_tiny_vexp (BF16 + VEXP attention)...");
    rt.compile("gpt_tiny_vexp")?;
    let toks = prompt(1);
    let t0 = Instant::now();
    let logits = rt.execute("gpt_tiny_vexp", &[Input::I32(&toks), Input::F32(&theta)])?;
    let lat = t0.elapsed();
    assert_eq!(logits.len(), SEQ * VOCAB);
    println!("batch-1 latency: {:.1} ms", lat.as_secs_f64() * 1e3);

    // --- batched serving (batch 8) --------------------------------------
    rt.compile("gpt_tiny_vexp_b8")?;
    let batch: Vec<i32> = (0..8).flat_map(prompt).collect();
    let t1 = Instant::now();
    let out = rt.execute("gpt_tiny_vexp_b8", &[Input::I32(&batch), Input::F32(&theta)])?;
    let bl = t1.elapsed();
    println!(
        "batch-8 latency: {:.1} ms -> {:.0} tokens/s on the CPU PJRT client",
        bl.as_secs_f64() * 1e3,
        (8 * SEQ) as f64 / bl.as_secs_f64()
    );

    // --- greedy next-token accuracy on the arithmetic task ---------------
    let mut correct = 0;
    let mut total = 0;
    for b in 0..8 {
        let toks = &batch[b * SEQ..(b + 1) * SEQ];
        let lg = &out[b * SEQ * VOCAB..(b + 1) * SEQ * VOCAB];
        for pos in 8..SEQ - 1 {
            let row = &lg[pos * VOCAB..(pos + 1) * VOCAB];
            let arg = row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
            total += 1;
            if arg as i32 == toks[pos + 1] {
                correct += 1;
            }
        }
    }
    println!(
        "greedy next-token accuracy on the synthetic task: {:.1}% ({correct}/{total})",
        100.0 * correct as f64 / total as f64
    );
    Ok(())
}

fn main() -> Result<()> {
    if let Err(e) = pjrt_leg() {
        println!("PJRT leg skipped ({e})");
    }

    // --- what serving this mix costs on the Occamy-style target ---------
    // Four concurrent requests (two tiny-GPT, a GPT-2, a ViT) through
    // the unified engine: compiled once via the program cache, packed
    // onto the 16 clusters, measured on the cycle-accurate backend and
    // rated by the analytic backend.
    let mut engine = Engine::new();
    for cfg in [TINY_GPT, TINY_GPT, GPT2_SMALL, VIT_BASE] {
        engine.submit(cfg);
    }
    let batch = engine.compile_batch();
    println!(
        "\nengine batch: {} requests, {} cached programs ({} hits / {} misses)",
        batch.requests.len(),
        engine.cache.len(),
        batch.cache_hits,
        batch.cache_misses
    );
    let mut sim = CycleSimBackend::new(CLUSTERS);
    let measured = sim.execute(&batch);
    let mut ana = AnalyticBackend::new();
    let rated = ana.execute(&batch);
    for (m, a) in measured.per_request.iter().zip(&rated.per_request) {
        println!(
            "  req {} {:12}: sim {:>9.0} cyc on {} clusters, analytic {:>9.0} cyc",
            m.request_id, m.model, m.cycles, m.clusters_used, a.cycles
        );
    }

    // --- full-model estimate for the tiny config (both directions) ------
    let b = ana.estimate(&Request::baseline(100, TINY_GPT));
    let o = ana.estimate(&Request::new(101, TINY_GPT));
    println!(
        "16-cluster estimate (tiny-GPT): baseline {:.3} ms vs VFEXP-optimized {:.3} ms \
         ({:.1}x), energy {:.3} mJ vs {:.3} mJ ({:.1}x)",
        b.latency_ms(),
        o.latency_ms(),
        b.cycles / o.cycles,
        b.energy_mj(),
        o.energy_mj(),
        b.energy_pj / o.energy_pj
    );
    Ok(())
}
