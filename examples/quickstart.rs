//! Quickstart: the full three-layer round trip in one page.
//!
//! 1. Load the AOT-compiled VEXP artifact (Pallas kernel, lowered by
//!    `make artifacts`) through the PJRT runtime (needs `--features
//!    pjrt`; skipped gracefully otherwise);
//! 2. compare it bit-for-bit with the Rust ExpUnit model;
//! 3. run the optimized softmax kernel on the cluster simulator and show
//!    the headline speedup.
//!
//! Run: `cargo run --release --example quickstart`

use vexp::bf16::Bf16;
use vexp::error::Result;
use vexp::kernels::softmax::{run_softmax, SoftmaxVariant};
use vexp::runtime::pjrt::Input;
use vexp::runtime::Runtime;
use vexp::vexp::exp_unit;

fn main() -> Result<()> {
    // --- Layer 1/2: execute the Pallas-authored kernel via PJRT --------
    let xs: Vec<f32> = (0..4096).map(|i| (i as f32 - 2048.0) * 0.01).collect();
    match Runtime::open("artifacts").and_then(|mut rt| rt.execute("vexp", &[Input::F32(&xs)])) {
        Ok(pjrt_out) => {
            // --- Layer 3: the bit-exact hardware model -----------------
            let mut mismatches = 0;
            for (i, &x) in xs.iter().enumerate() {
                if pjrt_out[i] != exp_unit(Bf16::from_f32(x)).to_f32() {
                    mismatches += 1;
                }
            }
            println!(
                "VEXP: PJRT artifact vs Rust ExpUnit over 4096 inputs: {mismatches} mismatches"
            );
            assert_eq!(mismatches, 0);
        }
        Err(e) => println!("VEXP PJRT cross-check skipped ({e})"),
    }

    // --- the paper's headline on the cluster simulator ------------------
    let rows: Vec<Vec<f32>> = (0..8)
        .map(|r| (0..1024).map(|i| ((i * 7 + r * 13) % 97) as f32 * 0.15 - 7.0).collect())
        .collect();
    let base = run_softmax(SoftmaxVariant::Baseline, &rows);
    let opt = run_softmax(SoftmaxVariant::SwExpHw, &rows);
    println!(
        "softmax 8x1024: baseline {:.0} cyc/out, VFEXP-optimized {:.2} cyc/out -> {:.0}x speedup (paper: 162.7x)",
        base.cycles_per_output,
        opt.cycles_per_output,
        base.cycles_per_output / opt.cycles_per_output
    );
    Ok(())
}
