//! Scenario: sweep the four softmax configurations over sequence lengths
//! (the Fig. 6a-c experiment as a library consumer would run it).
//!
//! Run: `cargo run --release --example softmax_comparison`

use vexp::energy::power::cluster_energy_pj;
use vexp::kernels::softmax::{run_softmax, softmax_ref, SoftmaxVariant};

fn main() {
    for n in [128usize, 512, 2048] {
        let rows: Vec<Vec<f32>> = (0..8)
            .map(|r| (0..n).map(|i| ((i * 11 + r * 17) % 89) as f32 * 0.2 - 8.0).collect())
            .collect();
        println!("=== sequence length {n} ===");
        for v in SoftmaxVariant::ALL {
            let run = run_softmax(v, &rows);
            // numeric sanity against the f32 oracle
            let mut max_err = 0.0f32;
            for (row, out) in rows.iter().zip(&run.out) {
                for (w, g) in softmax_ref(row).iter().zip(out) {
                    max_err = max_err.max((g - w).abs());
                }
            }
            let e = cluster_energy_pj(&run.stats, v == SoftmaxVariant::SwExpHw);
            println!(
                "{:24} {:>9.2} cyc/out  {:>10.1} pJ/out  max|err| {:.4}",
                v.label(),
                run.cycles_per_output,
                e.total() / (8 * n) as f64,
                max_err
            );
        }
    }
}
