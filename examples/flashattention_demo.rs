//! Scenario: FlashAttention-2 on one cluster with the GPT-2 head
//! configuration, checking numerics against exact attention and
//! reporting the Fig. 6d-f metrics; also cross-checks against the
//! PJRT-executed Pallas FA-2 artifact when built with `--features pjrt`.
//!
//! Run: `cargo run --release --example flashattention_demo`

use vexp::energy::power::cluster_energy_pj;
use vexp::error::Result;
use vexp::kernels::flash_attention::{attention_ref, run_flash_attention, FaVariant};
use vexp::runtime::pjrt::Input;
use vexp::runtime::Runtime;

fn mat(n: usize, seed: u64) -> Vec<f32> {
    let mut s = seed | 1;
    (0..n).map(|_| {
        s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
        ((s >> 33) as f64 / 2f64.powi(31) * 2.0 - 1.0) as f32
    }).collect()
}

fn main() -> Result<()> {
    let (sq, sk, d, bk) = (32u32, 128u32, 64u32, 32u32);
    let q = mat((sq * d) as usize, 1);
    let k = mat((sk * d) as usize, 2);
    let v = mat((sk * d) as usize, 3);

    let base = run_flash_attention(FaVariant::Baseline, &q, &k, &v, sq, sk, d, bk);
    let opt = run_flash_attention(FaVariant::Optimized, &q, &k, &v, sq, sk, d, bk);
    let want = attention_ref(&q, &k, &v, sq as usize, sk as usize, d as usize);
    let max_err = opt.out.iter().zip(&want).map(|(g, w)| (g - w).abs()).fold(0.0f32, f32::max);
    println!("simulator FA-2 vs exact attention: max|err| = {max_err:.4}");

    let eb = cluster_energy_pj(&base.stats, false).total();
    let eo = cluster_energy_pj(&opt.stats, true).total();
    println!(
        "speedup {:.1}x (paper: up to 8.2x), energy ratio {:.1}x (paper: up to 4.1x)",
        base.stats.cycles as f64 / opt.stats.cycles as f64,
        eb / eo
    );

    // cross-check against the Pallas artifact (128x64 Q, 256x64 K/V)
    let q2 = mat(128 * 64, 4);
    let k2 = mat(256 * 64, 5);
    let v2 = mat(256 * 64, 6);
    match Runtime::open("artifacts").and_then(|mut rt| {
        rt.execute("fa2_vexp", &[Input::F32(&q2), Input::F32(&k2), Input::F32(&v2)])
    }) {
        Ok(pj) => {
            let want2 = attention_ref(&q2, &k2, &v2, 128, 256, 64);
            let err2 = pj.iter().zip(&want2).map(|(g, w)| (g - w).abs()).fold(0.0f32, f32::max);
            println!("PJRT Pallas FA-2 artifact vs exact attention: max|err| = {err2:.4}");
        }
        Err(e) => println!("PJRT Pallas FA-2 cross-check skipped ({e})"),
    }
    Ok(())
}
